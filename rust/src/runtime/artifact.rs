//! Artifact manifest: discovery and bucket selection.
//!
//! `artifacts/manifest.txt` lines have the form
//!
//! ```text
//! <name> <kind> <dim0> [<dim1> ...] <file>
//! ```
//!
//! e.g. `matmul_nb128_n512 matmul1d 128 512 matmul_nb128_n512.hlo.txt`.
//! The runtime rounds a requested problem size *up* to the smallest bucket
//! that fits and rescales measured time by the unit ratio (documented in
//! [`super::real_exec`]).

use crate::error::{HfpmError, Result};
use std::path::{Path, PathBuf};

/// Kind of kernel an artifact implements.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    /// 1D local matmul: C[nb, n] = A[nb, n] · B[n, n]; dims = (nb, n).
    Matmul1d,
    /// Rank-1 update benchmark kernel; dims = (nb, n).
    Rank1,
    /// 2D pivot update; dims = (mb, nb, t).
    Block2d,
}

impl ArtifactKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "matmul1d" => Some(Self::Matmul1d),
            "rank1" => Some(Self::Rank1),
            "block2d" => Some(Self::Block2d),
            _ => None,
        }
    }
}

/// One artifact's metadata.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub kind: ArtifactKind,
    pub dims: Vec<u64>,
    pub path: PathBuf,
}

impl ArtifactMeta {
    /// Computation units of this bucket (product of the task dims; for
    /// matmul1d the local compute is nb·n·n units, for rank1 nb·n, for
    /// block2d mb·nb·t block-ops).
    pub fn units(&self) -> u64 {
        match self.kind {
            ArtifactKind::Matmul1d => self.dims[0] * self.dims[1] * self.dims[1],
            ArtifactKind::Rank1 => self.dims[0] * self.dims[1],
            ArtifactKind::Block2d => self.dims.iter().product(),
        }
    }
}

/// The parsed manifest with bucket lookup.
#[derive(Debug, Clone, Default)]
pub struct ArtifactManifest {
    pub artifacts: Vec<ArtifactMeta>,
    pub dir: PathBuf,
}

impl ArtifactManifest {
    /// Load `manifest.txt` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest).map_err(|e| {
            HfpmError::Artifact(format!(
                "cannot read {} — run `make artifacts` first ({e})",
                manifest.display()
            ))
        })?;
        Self::parse(&text, dir)
    }

    /// Default location: `$HFPM_ARTIFACTS` or `./artifacts`.
    pub fn load_default() -> Result<Self> {
        let dir = std::env::var("HFPM_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Self::load(Path::new(&dir))
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let mut artifacts = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split_whitespace().collect();
            if fields.len() < 4 {
                return Err(HfpmError::Artifact(format!(
                    "manifest line {}: expected `name kind dims... file`, got `{line}`",
                    lineno + 1
                )));
            }
            let kind = ArtifactKind::parse(fields[1]).ok_or_else(|| {
                HfpmError::Artifact(format!("unknown artifact kind `{}`", fields[1]))
            })?;
            let dims: Vec<u64> = fields[2..fields.len() - 1]
                .iter()
                .map(|d| {
                    d.parse::<u64>().map_err(|_| {
                        HfpmError::Artifact(format!("bad dim `{d}` on line {}", lineno + 1))
                    })
                })
                .collect::<Result<_>>()?;
            let expected_dims = match kind {
                ArtifactKind::Block2d => 3,
                _ => 2,
            };
            if dims.len() != expected_dims {
                return Err(HfpmError::Artifact(format!(
                    "artifact `{}`: expected {expected_dims} dims, got {}",
                    fields[0],
                    dims.len()
                )));
            }
            artifacts.push(ArtifactMeta {
                name: fields[0].to_string(),
                kind,
                dims,
                path: dir.join(fields[fields.len() - 1]),
            });
        }
        if artifacts.is_empty() {
            return Err(HfpmError::Artifact("manifest lists no artifacts".into()));
        }
        Ok(Self {
            artifacts,
            dir: dir.to_path_buf(),
        })
    }

    /// Smallest `matmul1d` bucket with `nb ≥ rows` and `n == cols` exactly
    /// (the B matrix can't be padded without changing the product), else
    /// the largest-nb bucket at that n (caller splits the work).
    pub fn matmul1d_bucket(&self, rows: u64, cols: u64) -> Result<&ArtifactMeta> {
        let mut candidates: Vec<&ArtifactMeta> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == ArtifactKind::Matmul1d && a.dims[1] == cols)
            .collect();
        if candidates.is_empty() {
            return Err(HfpmError::Artifact(format!(
                "no matmul1d artifact with n = {cols}; available: {:?}",
                self.artifacts
                    .iter()
                    .filter(|a| a.kind == ArtifactKind::Matmul1d)
                    .map(|a| a.dims[1])
                    .collect::<Vec<_>>()
            )));
        }
        candidates.sort_by_key(|a| a.dims[0]);
        Ok(candidates
            .iter()
            .find(|a| a.dims[0] >= rows)
            .copied()
            .unwrap_or_else(|| candidates[candidates.len() - 1]))
    }

    /// Smallest `rank1` bucket with `nb ≥ rows` (any n); falls back to the
    /// largest available. Used by the real-execution DFPA benchmark.
    pub fn rank1_bucket(&self, rows: u64) -> Result<&ArtifactMeta> {
        let mut candidates: Vec<&ArtifactMeta> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == ArtifactKind::Rank1)
            .collect();
        if candidates.is_empty() {
            return Err(HfpmError::Artifact("no rank1 artifacts in manifest".into()));
        }
        candidates.sort_by_key(|a| a.dims[0]);
        Ok(candidates
            .iter()
            .find(|a| a.dims[0] >= rows)
            .copied()
            .unwrap_or_else(|| candidates[candidates.len() - 1]))
    }

    /// Supported `n` values for the 1D kernel.
    pub fn matmul1d_ns(&self) -> Vec<u64> {
        let mut ns: Vec<u64> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == ArtifactKind::Matmul1d)
            .map(|a| a.dims[1])
            .collect();
        ns.sort_unstable();
        ns.dedup();
        ns
    }

    pub fn by_name(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
matmul_nb64_n256 matmul1d 64 256 matmul_nb64_n256.hlo.txt
matmul_nb128_n256 matmul1d 128 256 matmul_nb128_n256.hlo.txt
update_nb64_n512 rank1 64 512 update_nb64_n512.hlo.txt
blockupd_mb128_nb128_t64 block2d 128 128 64 blockupd_mb128_nb128_t64.hlo.txt
";

    #[test]
    fn parses_sample() {
        let m = ArtifactManifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.artifacts.len(), 4);
        assert_eq!(m.artifacts[0].kind, ArtifactKind::Matmul1d);
        assert_eq!(m.artifacts[3].dims, vec![128, 128, 64]);
        assert!(m.artifacts[0].path.ends_with("matmul_nb64_n256.hlo.txt"));
    }

    #[test]
    fn bucket_rounds_up() {
        let m = ArtifactManifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.matmul1d_bucket(50, 256).unwrap().dims[0], 64);
        assert_eq!(m.matmul1d_bucket(64, 256).unwrap().dims[0], 64);
        assert_eq!(m.matmul1d_bucket(65, 256).unwrap().dims[0], 128);
    }

    #[test]
    fn oversize_falls_back_to_largest() {
        let m = ArtifactManifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.matmul1d_bucket(10_000, 256).unwrap().dims[0], 128);
    }

    #[test]
    fn missing_n_is_error() {
        let m = ArtifactManifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert!(m.matmul1d_bucket(64, 1024).is_err());
    }

    #[test]
    fn units_per_kind() {
        let m = ArtifactManifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.artifacts[0].units(), 64 * 256 * 256); // matmul1d
        assert_eq!(m.artifacts[2].units(), 64 * 512); // rank1
        assert_eq!(m.artifacts[3].units(), 128 * 128 * 64); // block2d
    }

    #[test]
    fn rejects_garbage() {
        assert!(ArtifactManifest::parse("bad line\n", Path::new("/tmp")).is_err());
        assert!(ArtifactManifest::parse("", Path::new("/tmp")).is_err());
        assert!(
            ArtifactManifest::parse("x unknown 1 2 f.hlo.txt\n", Path::new("/tmp")).is_err()
        );
    }

    #[test]
    fn real_manifest_loads_if_built() {
        // integration-style: only runs when `make artifacts` has been run
        let dir = Path::new("artifacts");
        if dir.join("manifest.txt").exists() {
            let m = ArtifactManifest::load(dir).unwrap();
            assert!(!m.matmul1d_ns().is_empty());
        }
    }
}
