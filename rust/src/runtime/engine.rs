//! The PJRT execution engine: compile-once, execute-many.
//!
//! Wraps `xla::PjRtClient` (CPU) with an executable cache keyed by artifact
//! name. Adapted from the working reference at /opt/xla-example/load_hlo.

use super::artifact::{ArtifactManifest, ArtifactMeta};
use crate::error::{HfpmError, Result};
use std::collections::HashMap;
use std::time::Instant;

/// A compiled, executable kernel plus its metadata.
pub struct LoadedKernel {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

/// The engine owns the PJRT client and the executable cache.
pub struct PjrtEngine {
    client: xla::PjRtClient,
    manifest: ArtifactManifest,
    cache: HashMap<String, LoadedKernel>,
    /// Cumulative kernel wall time (profiling).
    pub total_exec_s: f64,
    /// Number of kernel executions.
    pub exec_count: u64,
}

impl PjrtEngine {
    /// Create a CPU engine over a manifest.
    pub fn new(manifest: ArtifactManifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Self {
            client,
            manifest,
            cache: HashMap::new(),
            total_exec_s: 0.0,
            exec_count: 0,
        })
    }

    /// Engine over the default artifacts directory.
    pub fn from_default_artifacts() -> Result<Self> {
        Self::new(ArtifactManifest::load_default()?)
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the artifact `name`.
    pub fn load(&mut self, name: &str) -> Result<&LoadedKernel> {
        if !self.cache.contains_key(name) {
            let meta = self
                .manifest
                .by_name(name)
                .ok_or_else(|| HfpmError::Artifact(format!("unknown artifact `{name}`")))?
                .clone();
            let path = meta.path.to_string_lossy().to_string();
            let proto = xla::HloModuleProto::from_text_file(&path).map_err(|e| {
                HfpmError::Artifact(format!("parse {path}: {e}"))
            })?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.cache.insert(meta.name.clone(), LoadedKernel { meta, exe });
        }
        Ok(&self.cache[name])
    }

    /// Execute artifact `name` on f32 input buffers (each `(data, shape)`),
    /// returning the first tuple element as a flat f32 vec + its wall time.
    ///
    /// All model functions return 1-tuples (lowered with
    /// `return_tuple=True`), matching `to_tuple1` here.
    pub fn execute_f32(
        &mut self,
        name: &str,
        inputs: &[(&[f32], &[usize])],
    ) -> Result<(Vec<f32>, f64)> {
        self.load(name)?;
        let kernel = &self.cache[name];
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs {
            let lit = xla::Literal::vec1(data);
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            literals.push(lit.reshape(&dims)?);
        }
        let start = Instant::now();
        let result = kernel.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        let dt = start.elapsed().as_secs_f64();
        self.total_exec_s += dt;
        self.exec_count += 1;
        let out = result.to_tuple1()?;
        Ok((out.to_vec::<f32>()?, dt))
    }

    /// Number of compiled executables held.
    pub fn cached(&self) -> usize {
        self.cache.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn engine() -> Option<PjrtEngine> {
        // these tests need `make artifacts` to have run
        let dir = Path::new("artifacts");
        if !dir.join("manifest.txt").exists() {
            eprintln!("skipping PJRT test: artifacts not built");
            return None;
        }
        Some(PjrtEngine::new(ArtifactManifest::load(dir).unwrap()).unwrap())
    }

    #[test]
    fn matmul_artifact_numerics() {
        let Some(mut e) = engine() else { return };
        let name = "matmul_nb64_n256";
        let nb = 64usize;
        let n = 256usize;
        // A = all 0.5, B = identity → C == A
        let a = vec![0.5f32; nb * n];
        let mut b = vec![0.0f32; n * n];
        for i in 0..n {
            b[i * n + i] = 1.0;
        }
        let (c, dt) = e
            .execute_f32(name, &[(&a, &[nb, n]), (&b, &[n, n])])
            .unwrap();
        assert_eq!(c.len(), nb * n);
        assert!(c.iter().all(|&x| (x - 0.5).abs() < 1e-5));
        assert!(dt > 0.0);
    }

    #[test]
    fn rank1_artifact_numerics() {
        let Some(mut e) = engine() else { return };
        let nb = 64usize;
        let n = 512usize;
        let c0 = vec![1.0f32; nb * n];
        let a = vec![2.0f32; nb];
        let b = vec![3.0f32; n];
        let (c, _) = e
            .execute_f32(
                "update_nb64_n512",
                &[(&c0, &[nb, n]), (&a, &[nb, 1]), (&b, &[1, n])],
            )
            .unwrap();
        // 1 + 2*3 = 7 everywhere
        assert!(c.iter().all(|&x| (x - 7.0).abs() < 1e-5));
    }

    #[test]
    fn executable_cache_reuses() {
        let Some(mut e) = engine() else { return };
        let a = vec![0.0f32; 64 * 256];
        let b = vec![0.0f32; 256 * 256];
        e.execute_f32("matmul_nb64_n256", &[(&a, &[64, 256]), (&b, &[256, 256])])
            .unwrap();
        e.execute_f32("matmul_nb64_n256", &[(&a, &[64, 256]), (&b, &[256, 256])])
            .unwrap();
        assert_eq!(e.cached(), 1);
        assert_eq!(e.exec_count, 2);
    }

    #[test]
    fn unknown_artifact_is_error() {
        let Some(mut e) = engine() else { return };
        assert!(e.execute_f32("nope", &[]).is_err());
    }
}
