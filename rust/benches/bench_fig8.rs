//! Regenerates **Fig 8**: the worked example of the two-step 2D CPM
//! distribution — a 6×6 block square over a 3×3 processor grid with
//! relative speeds {0.11, 0.25, 0.05, 0.17, 0.09, 0.08, 0.05, 0.17, 0.03}.
//! The paper's expected outcome is checked exactly.

use hfpm::partition::grid2d::two_step;
use hfpm::util::table::Table;

fn main() {
    let speeds = vec![
        vec![0.11, 0.25, 0.05],
        vec![0.17, 0.09, 0.08],
        vec![0.05, 0.17, 0.03],
    ];
    let g = two_step(6, 6, &speeds).expect("two-step distribution");

    let mut t = Table::new(
        "Fig 8 — two-step distribution of a 6×6 square over a 3×3 grid",
        &["", "col 1", "col 2", "col 3"],
    );
    t.add_row(vec![
        "widths".into(),
        g.col_widths[0].to_string(),
        g.col_widths[1].to_string(),
        g.col_widths[2].to_string(),
    ]);
    for i in 0..3 {
        t.add_row(vec![
            format!("row heights P{}*", i + 1),
            g.row_heights[0][i].to_string(),
            g.row_heights[1][i].to_string(),
            g.row_heights[2][i].to_string(),
        ]);
    }
    t.emit(Some(std::path::Path::new("results/bench/fig8.csv")));

    // the paper's exact numbers
    assert_eq!(g.col_widths, vec![2, 3, 1], "step (a): 0.33:0.51:0.16 ≈ 2:3:1");
    assert_eq!(g.row_heights[0], vec![2, 3, 1], "col 1: 0.11:0.17:0.05 ≈ 2:3:1");
    assert_eq!(g.row_heights[1], vec![3, 1, 2], "col 2: 0.25:0.09:0.17 ≈ 3:1:2");
    assert_eq!(g.row_heights[2], vec![2, 3, 1], "col 3: 0.05:0.08:0.03 ≈ 2:3:1");
    assert_eq!(g.total_area(), 36);
    println!("\nexact match with the paper's Fig 8 worked example ✓");

    // ASCII rendering of the distribution (the figure itself)
    println!("\n    col widths: 2 | 3 | 1");
    for i in 0..3 {
        let mut line = String::from("    ");
        for j in 0..3 {
            line.push_str(&format!(
                "P{}{}: {}×{}   ",
                i + 1,
                j + 1,
                g.row_heights[j][i],
                g.col_widths[j]
            ));
        }
        println!("{line}");
    }
}
