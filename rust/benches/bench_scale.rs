//! Nodes-vs-wall-clock scaling curve: the frame-synchronized engine
//! against the legacy thread-per-node runtime on synthetic heterogeneous
//! clusters up to 1000 nodes.
//!
//! Every point runs the same fixed superstep workload on both runtimes
//! (identically seeded executors) and checks that their *virtual* clocks
//! agree — the engine must be a faster way to compute the same numbers,
//! not different numbers. Wall-clock speedups land in `BENCH_scale.json`.
//!
//! Env knobs:
//! - `BENCH_SCALE_NODES="64,256"` — override the node counts (CI smoke);
//! - `BENCH_SCALE_OUT=path.json` — where to write the curve
//!   (default `BENCH_scale.json` in the cargo cwd, i.e. `rust/`);
//! - `BENCH_SCALE_STRICT=1` — fail if the engine is not ≥4× faster than
//!   legacy at ≥256 nodes (off by default: small CI hosts first).

use hfpm::cluster::comm::CommModel;
use hfpm::cluster::executor::NodeExecutor;
use hfpm::cluster::faults::FaultPlan;
use hfpm::cluster::node::build_nodes;
use hfpm::cluster::presets;
use hfpm::cluster::{Engine, LegacyCluster};
use hfpm::fpm::analytic::Footprint;
use hfpm::util::table::{fdur, fnum, Table};
use hfpm::util::timer::Stopwatch;

const STEPS: usize = 20;

fn executors(n: usize) -> (Vec<Box<dyn NodeExecutor>>, CommModel) {
    let spec = presets::synth(n);
    let nodes = build_nodes(&spec, Footprint::affine(16.0, 0.0), 32);
    let execs = nodes
        .into_iter()
        .map(|nd| Box::new(nd) as Box<dyn NodeExecutor>)
        .collect();
    (execs, CommModel::new(spec))
}

/// The per-step unit vector: mildly uneven so slots cost unequal work.
fn units(n: usize) -> Vec<u64> {
    (0..n).map(|i| 40_000 + 5_000 * (i % 7) as u64).collect()
}

struct Point {
    nodes: usize,
    engine_wall_s: f64,
    legacy_wall_s: f64,
    speedup: f64,
    virtual_s: f64,
    engine_workers: usize,
}

fn run_point(n: usize) -> Point {
    let d = units(n);

    let (execs, comm) = executors(n);
    let mut engine = Engine::spawn(execs, comm, FaultPlan::none());
    let sw = Stopwatch::start();
    for _ in 0..STEPS {
        engine.run_1d(&d).expect("engine step");
    }
    let engine_wall_s = sw.elapsed_s();
    let engine_virtual = engine.now();
    let engine_workers = engine.worker_threads();

    let (execs, comm) = executors(n);
    let mut legacy = LegacyCluster::spawn(execs, comm, FaultPlan::none());
    let sw = Stopwatch::start();
    for _ in 0..STEPS {
        legacy.run_1d(&d).expect("legacy step");
    }
    let legacy_wall_s = sw.elapsed_s();
    let legacy_virtual = legacy.now();

    // same executors, same fold order: the virtual clocks must agree to
    // f64 rounding — the engine computes the same numbers, faster
    let rel = (engine_virtual - legacy_virtual).abs() / legacy_virtual.max(f64::MIN_POSITIVE);
    assert!(
        rel < 1e-9,
        "virtual-clock divergence at {n} nodes: engine {engine_virtual} vs legacy {legacy_virtual}"
    );

    Point {
        nodes: n,
        engine_wall_s,
        legacy_wall_s,
        speedup: legacy_wall_s / engine_wall_s.max(f64::MIN_POSITIVE),
        virtual_s: engine_virtual,
        engine_workers,
    }
}

fn json(points: &[Point]) -> String {
    let mut out = String::from("{\n  \"bench\": \"bench_scale\",\n");
    out.push_str(&format!("  \"steps\": {STEPS},\n  \"points\": [\n"));
    for (i, p) in points.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"nodes\": {}, \"engine_wall_s\": {:.6}, \"legacy_wall_s\": {:.6}, \
             \"speedup\": {:.3}, \"virtual_s\": {:.6}, \"engine_workers\": {}}}{}\n",
            p.nodes,
            p.engine_wall_s,
            p.legacy_wall_s,
            p.speedup,
            p.virtual_s,
            p.engine_workers,
            if i + 1 < points.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

fn main() {
    let counts: Vec<usize> = match std::env::var("BENCH_SCALE_NODES") {
        Ok(v) => v
            .split(',')
            .map(|s| s.trim().parse().expect("BENCH_SCALE_NODES: bad count"))
            .collect(),
        Err(_) => vec![16, 64, 256, 1000],
    };

    let mut t = Table::new(
        &format!("cluster engine scaling ({STEPS} supersteps per point)"),
        &["nodes", "pool", "engine wall", "legacy wall", "speedup", "virtual_s"],
    );
    let mut points = Vec::new();
    for &n in &counts {
        let p = run_point(n);
        t.add_row(vec![
            p.nodes.to_string(),
            p.engine_workers.to_string(),
            fdur(p.engine_wall_s),
            fdur(p.legacy_wall_s),
            format!("{}x", fnum(p.speedup, 2)),
            fnum(p.virtual_s, 3),
        ]);
        points.push(p);
    }
    print!("{}", t.render());

    let strict = std::env::var("BENCH_SCALE_STRICT").is_ok();
    for p in points.iter().filter(|p| p.nodes >= 256) {
        if p.speedup < 4.0 {
            let msg = format!(
                "engine speedup at {} nodes is only {:.2}x (< 4x target)",
                p.nodes, p.speedup
            );
            if strict {
                panic!("{msg}");
            }
            eprintln!("warn: {msg}");
        }
    }

    let out = std::env::var("BENCH_SCALE_OUT").unwrap_or_else(|_| "BENCH_scale.json".into());
    std::fs::write(&out, json(&points)).expect("write BENCH_scale.json");
    println!("json: {out}");
}
