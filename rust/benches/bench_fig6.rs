//! Regenerates **Fig 6**: DFPA execution steps for n = 5120, p = 15,
//! ε = 2.5% — the paging-borderline case. The paper watches four
//! representative processors (hcl03, hcl06, hcl08, hcl16): the 256 MiB
//! nodes start paging at the even distribution, get small slices, and the
//! algorithm converges once the cliff is mapped.

use hfpm::apps::matmul1d::{build_cluster, Matmul1dConfig, RowBench, Strategy};
use hfpm::cluster::presets;
use hfpm::dfpa::{run_dfpa, DfpaOptions, IterationRecord};
use hfpm::util::table::Table;
use std::path::Path;

fn main() {
    let n = 5120u64;
    let spec = presets::hcl15();
    let cfg = Matmul1dConfig::new(n, Strategy::Dfpa);
    let (mut cluster, nodes) = build_cluster(&spec, &cfg, Default::default()).unwrap();
    let mut bench = RowBench {
        cluster: &mut cluster,
        n,
    };
    let r = run_dfpa(n, &mut bench, DfpaOptions::with_epsilon(0.025)).unwrap();

    let watch = ["hcl03", "hcl06", "hcl08", "hcl16"];
    let idx: Vec<usize> = watch
        .iter()
        .map(|h| nodes.iter().position(|nd| &nd.spec.host == h).unwrap())
        .collect();

    let mut t = Table::new(
        "Fig 6 — DFPA steps, n = 5120, ε = 2.5% (rows | speed Mu/s)",
        &["iter", "hcl03", "hcl06", "hcl08", "hcl16", "imbalance"],
    );
    for rec in &r.records {
        let cell = |i: usize| {
            format!(
                "{} | {:.0}",
                rec.d[idx[i]],
                rec.speeds[idx[i]] / 1e6 * n as f64 // units/s = rows/s · n
            )
        };
        t.add_row(vec![
            rec.iter.to_string(),
            cell(0),
            cell(1),
            cell(2),
            cell(3),
            format!("{:.3}", rec.imbalance),
        ]);
    }
    t.emit(None);
    let csv = Path::new("results/bench/fig6_trace.csv");
    IterationRecord::write_csv(&r.records, csv).unwrap();
    println!("full per-processor trace: {}", csv.display());

    // shape checks per the paper's narrative
    assert!(r.converged, "DFPA must converge (imbalance {})", r.imbalance);
    let first = &r.records[0];
    let last = r.records.last().unwrap();
    let h06 = idx[1];
    let h16 = idx[3];
    // at the even distribution the 256 MiB node pages → slow speed
    assert!(
        first.speeds[h06] < 0.7 * first.speeds[h16],
        "hcl06 should start much slower than hcl16 (paging): {:.1} vs {:.1}",
        first.speeds[h06],
        first.speeds[h16]
    );
    // after convergence it holds fewer rows than the healthy node
    assert!(
        last.d[h06] < last.d[h16],
        "hcl06 should end with fewer rows: {} vs {}",
        last.d[h06],
        last.d[h16]
    );
    println!(
        "\nshape checks passed: paging nodes start slow, end with smaller slices; {} iterations",
        r.iterations
    );
}
