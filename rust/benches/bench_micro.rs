//! L3 micro-benchmarks (criterion-lite): the coordinator hot paths that
//! §Perf of EXPERIMENTS.md tracks — geometric partitioning, piecewise
//! model evaluation/insertion, integer finishing, cluster supersteps and
//! whole DFPA runs. Wall time, not virtual time.
//!
//! `cargo bench --bench bench_micro [filter] [--quick]`

use hfpm::adapt::{Dfpa, Distributor, SessionCtx};
use hfpm::apps::matmul1d::{build_cluster, Matmul1dConfig, Strategy};
use hfpm::bench_harness::{main_with, random_piecewise_models, OwnedRowBench};
use hfpm::cluster::presets;
use hfpm::fpm::{PiecewiseModel, SpeedFunction};
use hfpm::partition::{self, hsp};
use hfpm::util::rng::Pcg32;

fn random_models(p: usize, points: usize, seed: u64) -> Vec<PiecewiseModel> {
    random_piecewise_models(p, points, seed, 200.0, 900.0)
}

fn main() {
    main_with("micro", |g| {
        // --- geometric partitioner ---
        for (p, pts) in [(15usize, 8usize), (15, 32), (128, 8)] {
            let models = random_models(p, pts, 42);
            g.bench(&format!("partition/geometric p={p} pts={pts}"), |b| {
                b.throughput(p as u64);
                b.iter(|| partition::partition(1_000_000, &models).unwrap());
            });
        }

        // --- piecewise model ops ---
        let model = &random_models(1, 64, 7)[0];
        g.bench("piecewise/eval 64-pt model", |b| {
            let mut x = 1.0f64;
            b.iter(|| {
                x = (x * 1.618) % 1e7 + 1.0;
                std::hint::black_box(model.speed(x))
            });
        });
        g.bench("piecewise/insert into 64-pt model", |b| {
            let mut rng = Pcg32::seeded(3);
            b.iter(|| {
                let mut m = model.clone();
                m.insert(rng.uniform(1.0, 1e7), rng.uniform(1.0, 900.0));
                m
            });
        });

        // --- integer finishing ---
        let mut rng = Pcg32::seeded(11);
        let reals: Vec<f64> = (0..128).map(|_| rng.uniform(0.0, 1e4)).collect();
        let n: u64 = reals.iter().sum::<f64>().round() as u64;
        g.bench("hsp/round_to_sum p=128", |b| {
            b.iter(|| hsp::round_to_sum(&reals, n));
        });
        let models128 = random_models(128, 8, 13);
        g.bench("hsp/refine p=128", |b| {
            let d0 = hsp::round_to_sum(&reals, n);
            b.iter(|| {
                let mut d = d0.clone();
                hsp::refine(&mut d, &models128);
                d
            });
        });

        // --- cluster superstep (leader/worker round trip) ---
        g.bench("cluster/superstep 16 workers", |b| {
            let spec = presets::hcl();
            let cfg = Matmul1dConfig::new(4096, Strategy::Dfpa);
            let (mut cluster, _) = build_cluster(&spec, &cfg, Default::default()).unwrap();
            let d = vec![1_000_000u64; 16];
            b.iter(|| cluster.run_1d(&d).unwrap());
        });

        // --- whole DFPA runs (wall cost of the algorithm itself), driven
        // through the adapt layer's Distributor API ---
        for n in [4096u64, 8192] {
            let spec = presets::hcl15();
            g.bench_distribute(
                &format!("dfpa/full run hcl15 n={n}"),
                n,
                &SessionCtx::with_epsilon(0.025),
                || {
                    let cfg = Matmul1dConfig::new(n, Strategy::Dfpa);
                    let (cluster, _) =
                        build_cluster(&spec, &cfg, Default::default()).unwrap();
                    (
                        Box::new(Dfpa::default()) as Box<dyn Distributor>,
                        OwnedRowBench { cluster, n },
                    )
                },
            );
        }

        // --- the bi-objective distributor (dual-model learning + front
        // construction every iteration) against plain DFPA above ---
        {
            let n = 4096u64;
            let spec = presets::hcl15();
            g.bench_distribute(
                &format!("biobj/full run hcl15 n={n} w=0.5"),
                n,
                &SessionCtx::with_epsilon(0.025),
                move || {
                    let cfg = Matmul1dConfig::new(n, Strategy::Dfpa);
                    let (cluster, _) =
                        build_cluster(&spec, &cfg, Default::default()).unwrap();
                    (
                        Box::new(hfpm::biobj::BiObj::new(0.5)) as Box<dyn Distributor>,
                        OwnedRowBench { cluster, n },
                    )
                },
            );
        }

        // --- iterative workloads over the session (coordinator wall cost
        // of a whole rebalanced run, cluster spawn included) ---
        g.bench("jacobi/app mini4 n=512 (12 sweeps, rebal 4)", |b| {
            let spec = presets::mini4();
            b.iter(|| {
                let cfg = hfpm::apps::JacobiConfig::new(512, Strategy::Dfpa);
                hfpm::apps::jacobi::run(&spec, &cfg).unwrap()
            });
        });
        g.bench("lu/app mini4 n=512 b=32 (16 panels)", |b| {
            let spec = presets::mini4();
            b.iter(|| {
                let mut cfg = hfpm::apps::LuConfig::new(512, Strategy::Dfpa);
                cfg.block = 32;
                hfpm::apps::lu::run(&spec, &cfg).unwrap()
            });
        });

        // --- comm model arithmetic ---
        g.bench("comm/dfpa_iteration_cost grid5000", |b| {
            let m = hfpm::cluster::comm::CommModel::new(presets::grid5000());
            b.iter(|| m.dfpa_iteration_cost(0));
        });
    });
}
