//! Regenerates **Table 5**: cost of the DFPA-based heterogeneous 2D matrix
//! multiplication on 16 HCL nodes — total time, DFPA time, iterations,
//! matmul time and DFPA cost %. The paper's shape: cost % creeps from
//! ~0.2% at n = 8192 to ~17% at n = 19456 as paging territory grows.

use hfpm::apps::matmul2d::{run, Matmul2dConfig};
use hfpm::apps::Strategy;
use hfpm::cluster::presets;
use hfpm::util::table::{fnum, Table};

// paper rows: (n, total, dfpa_s, iters, matmul, cost_pct)
const PAPER: &[(u64, f64, f64, u64, f64, f64)] = &[
    (8192, 61.91, 0.17, 16, 61.74, 0.28),
    (9216, 65.91, 0.14, 11, 65.76, 0.21),
    (10240, 105.22, 0.19, 13, 105.02, 0.18),
    (11264, 137.34, 0.22, 15, 137.11, 0.16),
    (13312, 246.49, 5.84, 44, 240.65, 2.36),
    (14336, 264.45, 16.25, 62, 248.20, 6.14),
    (15360, 311.28, 24.06, 69, 287.22, 7.73),
    (16384, 448.27, 28.44, 71, 419.83, 6.34),
    (17408, 483.23, 52.51, 69, 430.71, 10.86),
    (19456, 770.00, 131.45, 74, 638.55, 17.07),
];

fn main() {
    let spec = presets::hcl();
    let mut t = Table::new(
        "Table 5 — DFPA-based 2D matmul on 16 HCL nodes",
        &[
            "n", "total (s)", "DFPA (s)", "iters", "matmul (s)", "cost %",
            "paper iters", "paper cost %",
        ],
    );
    let mut costs = Vec::new();
    for &(n, _, _, p_iters, _, p_cost) in PAPER {
        let mut cfg = Matmul2dConfig::new(n, Strategy::Dfpa);
        cfg.epsilon = 0.1;
        let r = run(&spec, &cfg).expect("2d run");
        costs.push(r.overhead_pct);
        t.add_row(vec![
            n.to_string(),
            fnum(r.total_s, 2),
            fnum(r.partition_s, 3),
            r.iterations.to_string(),
            fnum(r.matmul_s, 2),
            fnum(r.overhead_pct, 2),
            p_iters.to_string(),
            fnum(p_cost, 2),
        ]);
    }
    t.emit(Some(std::path::Path::new("results/bench/table5.csv")));

    // shape: the late (paging) sizes must cost relatively more than the
    // early ones
    let early: f64 = costs[..4].iter().sum::<f64>() / 4.0;
    let late: f64 = costs[costs.len() - 3..].iter().sum::<f64>() / 3.0;
    println!(
        "\nmean DFPA cost: {:.2}% early sizes vs {:.2}% paging sizes (paper: 0.2% → ~12%)",
        early, late
    );
    assert!(
        late >= early,
        "cost % should not shrink as paging grows: {late} < {early}"
    );
}
