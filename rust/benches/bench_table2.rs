//! Regenerates **Table 2**: FFMPA-based vs DFPA-based 1D application on the
//! 15-node HCL cluster (ε = 2.5%), n = 2048…8192.
//!
//! Paper reference (15 procs, excl. hcl07):
//!   n=2048: FFMPA 3.16s, DFPA-app 3.43s, ratio 1.06, DFPA 0.22s, 4 iters
//!   n=8192: FFMPA 280.04s, DFPA-app 308.88s, ratio 1.10, DFPA 28.84s, 5 iters
//! Full-model construction: 1850 s over 160 points.
//!
//! Absolute seconds differ (simulated testbed); the *shape* must hold:
//! ratio ∈ [1.0, 1.15], DFPA cost ≪ app, few iterations, and the model
//! build orders of magnitude above DFPA.

use hfpm::apps::matmul1d::{run, Matmul1dConfig, Strategy};
use hfpm::baselines::ffmpa;
use hfpm::cluster::node::build_nodes;
use hfpm::cluster::presets;
use hfpm::fpm::analytic::Footprint;
use hfpm::util::table::{fnum, Table};

// paper's Table 2 rows: (n, ffmpa_s, dfpa_app_s, ratio, dfpa_s, iters)
const PAPER: &[(u64, f64, f64, f64, f64, u64)] = &[
    (2048, 3.16, 3.43, 1.06, 0.22, 4),
    (3072, 10.70, 11.02, 1.02, 0.30, 2),
    (4096, 25.42, 25.87, 1.01, 0.43, 2),
    (5120, 52.61, 57.62, 1.09, 4.96, 11),
    (6144, 101.45, 112.19, 1.10, 10.74, 3),
    (7168, 183.79, 203.36, 1.10, 19.55, 5),
    (8192, 280.04, 308.88, 1.10, 28.84, 5),
];

fn main() {
    let spec = presets::hcl15();
    let mut t = Table::new(
        "Table 2 — FFMPA vs DFPA 1D application, 15 HCL nodes, ε = 2.5%",
        &[
            "n", "FFMPA app (s)", "DFPA app (s)", "ratio", "DFPA (s)", "iters",
            "paper ratio", "paper iters",
        ],
    );
    for &(n, _, _, p_ratio, _, p_iters) in PAPER {
        let mut cfg_f = Matmul1dConfig::new(n, Strategy::Ffmpa);
        cfg_f.epsilon = 0.025;
        let rf = run(&spec, &cfg_f).expect("ffmpa run");
        let mut cfg_d = Matmul1dConfig::new(n, Strategy::Dfpa);
        cfg_d.epsilon = 0.025;
        let rd = run(&spec, &cfg_d).expect("dfpa run");
        let ratio = rd.total_s / rf.total_s;
        t.add_row(vec![
            n.to_string(),
            fnum(rf.total_s, 2),
            fnum(rd.total_s, 2),
            fnum(ratio, 3),
            fnum(rd.partition_s, 2),
            rd.iterations.to_string(),
            fnum(p_ratio, 2),
            p_iters.to_string(),
        ]);
    }
    t.emit(Some(std::path::Path::new("results/bench/table2.csv")));

    // the model-construction comparison quoted next to Table 2
    let nodes = build_nodes(&spec, Footprint::matmul_1d(8192), 32);
    let full = ffmpa::full_grid_build_cost(&nodes, 8192);
    println!(
        "\nfull-FPM construction: {:.1}s (modeled, parallel) over {} points per processor",
        full.parallel_s, full.points_per_proc
    );
    println!("paper: 1850s over 160 points — DFPA needs ≤ ~11 in-band points instead");
}
