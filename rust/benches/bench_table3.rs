//! Regenerates **Table 3**: the DFPA-based application with ε = 10% vs
//! ε = 2.5% on 15 HCL nodes — the paper's point being that tightening ε
//! adds a few iterations but similar distributions and negligible cost.

use hfpm::apps::matmul1d::{run, Matmul1dConfig, Strategy};
use hfpm::cluster::presets;
use hfpm::util::table::{fnum, Table};

// paper rows: (n, mm10, dfpa10, it10, mm25, dfpa25, it25)
const PAPER: &[(u64, f64, f64, u64, f64, f64, u64)] = &[
    (2048, 3.21, 0.22, 4, 3.16, 0.23, 6),
    (3072, 10.72, 0.30, 2, 10.70, 0.31, 3),
    (4096, 25.44, 0.43, 2, 25.42, 0.49, 4),
    (5120, 52.66, 4.96, 11, 52.61, 6.18, 11),
    (6144, 101.45, 10.74, 3, 101.45, 11.83, 4),
    (7168, 183.81, 19.55, 5, 183.79, 21.05, 5),
    (8192, 280.04, 28.84, 5, 280.04, 26.78, 5),
];

fn main() {
    let spec = presets::hcl15();
    let mut t = Table::new(
        "Table 3 — DFPA app at ε = 10% vs 2.5%, 15 HCL nodes",
        &[
            "n",
            "matmul (s) 10%", "DFPA (s) 10%", "iters 10%",
            "matmul (s) 2.5%", "DFPA (s) 2.5%", "iters 2.5%",
            "paper iters 10/2.5",
        ],
    );
    for &(n, _, _, p10, _, _, p25) in PAPER {
        let mut row = vec![n.to_string()];
        let mut iters = Vec::new();
        for eps in [0.10, 0.025] {
            let mut cfg = Matmul1dConfig::new(n, Strategy::Dfpa);
            cfg.epsilon = eps;
            let r = run(&spec, &cfg).expect("run");
            row.push(fnum(r.compute_s, 2));
            row.push(fnum(r.partition_s, 3));
            row.push(r.iterations.to_string());
            iters.push(r.iterations);
        }
        row.push(format!("{p10}/{p25}"));
        t.add_row(row);
        // shape check mirrors the paper: tighter ε never needs fewer steps
        assert!(
            iters[1] >= iters[0],
            "n={n}: ε=2.5% used fewer iterations than ε=10%"
        );
    }
    t.emit(Some(std::path::Path::new("results/bench/table3.csv")));
    println!("\nshape check passed: iterations(2.5%) ≥ iterations(10%) for every n");
}
