//! Bi-objective sweep: the wall cost of Pareto-front construction (the
//! per-iteration overhead `biobj` adds on top of DFPA's partitioning) and
//! whole `biobj:<w>` runs across the weight range on the preset clusters.
//!
//! `cargo bench --bench bench_pareto [filter] [--quick]`

use hfpm::adapt::{Distributor, SessionCtx};
use hfpm::apps::matmul1d::{build_cluster, Matmul1dConfig, Strategy};
use hfpm::bench_harness::{main_with, random_piecewise_models as random_models, OwnedRowBench};
use hfpm::biobj::{build_front, BiObj, ParetoOptions};
use hfpm::cluster::presets;
use hfpm::partition::GeometricOptions;

fn main() {
    main_with("pareto", |g| {
        // --- front construction: the biobj-specific hot path ---
        for (p, levels) in [(4usize, 16usize), (15, 8), (15, 16), (15, 32), (28, 16)] {
            let speed = random_models(p, 8, 42, 200.0, 900.0);
            let energy = random_models(p, 8, 43, 1e-8, 9e-8);
            let opts = ParetoOptions {
                levels,
                ..Default::default()
            };
            g.bench(&format!("front/build p={p} levels={levels}"), |b| {
                b.throughput(p as u64);
                b.iter(|| {
                    build_front(
                        1_000_000,
                        &speed,
                        Some(&energy),
                        GeometricOptions::default(),
                        &opts,
                    )
                    .unwrap()
                });
            });
        }

        // --- scalarized selection over a built front ---
        {
            let speed = random_models(15, 8, 42, 200.0, 900.0);
            let energy = random_models(15, 8, 43, 1e-8, 9e-8);
            let front = build_front(
                1_000_000,
                &speed,
                Some(&energy),
                GeometricOptions::default(),
                &ParetoOptions::default(),
            )
            .unwrap();
            g.bench("front/scalarized select", |b| {
                let mut w = 0.0f64;
                b.iter(|| {
                    w = (w + 0.37) % 1.0;
                    std::hint::black_box(front.scalarized(w))
                });
            });
        }

        // --- whole biobj runs across the weight range (hcl15, same shape
        // as bench_micro's dfpa entry for apples-to-apples reading) ---
        for w in [0.0f64, 0.5, 1.0] {
            let n = 4096u64;
            let spec = presets::hcl15();
            g.bench_distribute(
                &format!("biobj/full run hcl15 n={n} w={w:.1}"),
                n,
                &SessionCtx::with_epsilon(0.025),
                move || {
                    let cfg = Matmul1dConfig::new(n, Strategy::Dfpa);
                    let (cluster, _) =
                        build_cluster(&spec, &cfg, Default::default()).unwrap();
                    (
                        Box::new(BiObj::new(w)) as Box<dyn Distributor>,
                        OwnedRowBench { cluster, n },
                    )
                },
            );
        }
    });
}
