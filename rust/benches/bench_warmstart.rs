//! Cold vs warm DFPA: the self-adaptable-application scenario.
//!
//! The paper's target use case is an application invoked repeatedly on the
//! same platform. This bench simulates a sequence of invocations of the 1D
//! matmul app on the 15-node HCL testbed, once without a model store
//! (every invocation rediscovers the platform) and once with a persistent
//! store warm-starting every invocation after the first. Reported per
//! invocation: DFPA benchmark iterations and the partition-phase virtual
//! cost — the quantity the store amortizes toward the single validation
//! step.
//!
//! Run: `cargo bench --bench bench_warmstart`

use hfpm::apps::matmul1d::{run, Matmul1dConfig, Strategy};
use hfpm::cluster::presets;
use hfpm::modelstore::ModelStore;
use hfpm::util::table::{fdur, fnum, Table};

fn main() {
    let spec = presets::hcl15();
    let n = 5120u64;
    let invocations = 6usize;

    let store_dir = std::env::temp_dir().join(format!(
        "hfpm-bench-warmstart-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&store_dir);

    let mut t = Table::new(
        &format!("cold vs warm-started DFPA (1D matmul, `{}`, n = {n}, ε = 2.5%)", spec.name),
        &[
            "invocation",
            "cold iters",
            "cold partition (s)",
            "warm iters",
            "warm partition (s)",
            "warm/cold cost %",
        ],
    );

    let mut cold_total = 0.0f64;
    let mut warm_total = 0.0f64;
    for k in 0..invocations {
        // cold: no store — every invocation starts from the even split
        let mut cold_cfg = Matmul1dConfig::new(n, Strategy::Dfpa);
        cold_cfg.epsilon = 0.025;
        let cold = run(&spec, &cold_cfg).expect("cold run");
        assert!(!cold.warm_started);

        // warm: persistent store shared across invocations
        let mut warm_cfg = Matmul1dConfig::new(n, Strategy::Dfpa);
        warm_cfg.epsilon = 0.025;
        warm_cfg.model_store = Some(store_dir.clone());
        let warm = run(&spec, &warm_cfg).expect("warm run");
        assert_eq!(warm.warm_started, k > 0, "store warms every run after the first");
        if k > 0 {
            assert!(
                warm.iterations < cold.iterations,
                "invocation {k}: warm {} !< cold {}",
                warm.iterations,
                cold.iterations
            );
        }

        cold_total += cold.partition_s;
        warm_total += warm.partition_s;
        t.add_row(vec![
            format!("{}", k + 1),
            cold.iterations.to_string(),
            fdur(cold.partition_s),
            warm.iterations.to_string(),
            fdur(warm.partition_s),
            fnum(100.0 * warm.partition_s / cold.partition_s.max(1e-12), 1),
        ]);
    }
    t.add_row(vec![
        "Σ".into(),
        String::new(),
        fdur(cold_total),
        String::new(),
        fdur(warm_total),
        fnum(100.0 * warm_total / cold_total.max(1e-12), 1),
    ]);
    t.emit(Some(std::path::Path::new("results/bench/warmstart.csv")));

    let store = ModelStore::open(&store_dir).expect("store exists");
    println!(
        "store: {} models in {}",
        store.entries().map(|e| e.len()).unwrap_or(0),
        store.dir().display()
    );
    let _ = std::fs::remove_dir_all(&store_dir);
}
