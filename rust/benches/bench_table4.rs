//! Regenerates **Table 4**: the DFPA-based application on the 28-node
//! Grid5000-like platform (ε = 10% and 2.5%) — large-RAM nodes keep the
//! problem out of paging, so DFPA converges in ≤3 iterations and costs
//! under ~1% of the application.

use hfpm::apps::matmul1d::{run, Matmul1dConfig, Strategy};
use hfpm::cluster::presets;
use hfpm::util::table::{fnum, Table};

// paper rows: (n, mm10, dfpa10, it10, mm25, dfpa25, it25)
const PAPER: &[(u64, f64, f64, u64, f64, f64, u64)] = &[
    (7168, 65.88, 1.19, 2, 65.71, 1.24, 3),
    (10240, 193.05, 2.02, 2, 192.67, 2.12, 3),
    (12288, 334.32, 2.65, 2, 333.87, 2.74, 3),
];

fn main() {
    let spec = presets::grid5000();
    println!(
        "cluster `{}`: {} nodes over {} sites, heterogeneity {:.2} (paper: 2.5–2.8)\n",
        spec.name,
        spec.size(),
        spec.nodes.iter().map(|n| n.site).max().unwrap() + 1,
        spec.peak_heterogeneity()
    );
    let mut t = Table::new(
        "Table 4 — DFPA app on Grid5000 (28 nodes), ε = 10% / 2.5%",
        &[
            "n",
            "matmul (s) 10%", "DFPA (s) 10%", "iters 10%",
            "matmul (s) 2.5%", "DFPA (s) 2.5%", "iters 2.5%",
            "paper iters 10/2.5",
        ],
    );
    for &(n, _, _, p10, _, _, p25) in PAPER {
        let mut row = vec![n.to_string()];
        for eps in [0.10, 0.025] {
            let mut cfg = Matmul1dConfig::new(n, Strategy::Dfpa);
            cfg.epsilon = eps;
            let r = run(&spec, &cfg).expect("run");
            row.push(fnum(r.compute_s, 2));
            row.push(fnum(r.partition_s, 3));
            row.push(r.iterations.to_string());
            // the headline claims of Table 4. (ε = 2.5% sits near the
            // simulated platform's noise floor, so the plateau detector
            // may spend a few extra refinement iterations than the paper's
            // quieter testbed needed.)
            assert!(
                r.iterations <= 15,
                "n={n} ε={eps}: {} iterations (paper: ≤3)",
                r.iterations
            );
            assert!(
                r.partition_s / r.total_s < 0.05,
                "n={n} ε={eps}: DFPA cost {:.2}% (paper: <1%)",
                100.0 * r.partition_s / r.total_s
            );
        }
        row.push(format!("{p10}/{p25}"));
        t.add_row(row);
    }
    t.emit(Some(std::path::Path::new("results/bench/table4.csv")));
    println!("\nshape checks passed: few iterations, DFPA cost ≪ app");
}
