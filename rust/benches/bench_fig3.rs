//! Regenerates **Fig 3**: relative processor speeds for a naive matrix
//! multiplication across the cache and main-memory ranges — four HCL
//! nodes, speed as a function of problem size, showing the cache cliff
//! and the divergence of *relative* speeds that breaks CPMs.

use hfpm::cluster::presets;
use hfpm::fpm::analytic::{AnalyticModel, Footprint};
use hfpm::fpm::builder::log_grid;
use hfpm::fpm::SpeedFunction;
use hfpm::util::csv::CsvWriter;
use hfpm::util::table::{fnum, Table};
use std::path::Path;

fn main() {
    let spec = presets::hcl();
    // the four most contrasting nodes: fast-bus Xeon, Opteron, P4, Celeron
    let hosts = ["hcl01", "hcl09", "hcl11", "hcl13"];
    let models: Vec<(String, AnalyticModel)> = hosts
        .iter()
        .map(|h| {
            let nd = spec.nodes.iter().find(|n| &n.host == h).unwrap();
            (
                h.to_string(),
                // pure kernel footprint (no fixed B term): exposes the
                // cache→memory transition cleanly, as Fig 3 does
                AnalyticModel::from_spec(nd, Footprint::affine(16.0, 0.0)),
            )
        })
        .collect();

    // sweep from deep-cache to deep-memory (units)
    let grid = log_grid(1e3, 5e7, 48);
    let mut headers = vec!["units".to_string(), "bytes".to_string()];
    headers.extend(hosts.iter().map(|h| h.to_string()));
    let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let csv_path = Path::new("results/bench/fig3.csv");
    let mut csv = CsvWriter::create(csv_path, &header_refs).unwrap();
    for &x in &grid {
        let mut row = vec![x, 16.0 * x];
        for (_, m) in &models {
            row.push(m.speed(x) / 1e6); // Munits/s
        }
        csv.row_f64(&row, 3).unwrap();
    }
    csv.flush().unwrap();

    // table of speeds + relative speeds at three representative sizes
    let mut t = Table::new(
        "Fig 3 — absolute speed (Munits/s) in cache / memory ranges",
        &["size", "hcl01", "hcl09", "hcl11", "hcl13", "rel. 01/13"],
    );
    for (label, x) in [("in-cache (32 KB)", 2e3), ("boundary (1 MB)", 6.5e4), ("in-RAM (80 MB)", 5e6)] {
        let speeds: Vec<f64> = models.iter().map(|(_, m)| m.speed(x) / 1e6).collect();
        t.add_row(vec![
            label.to_string(),
            fnum(speeds[0], 0),
            fnum(speeds[1], 0),
            fnum(speeds[2], 0),
            fnum(speeds[3], 0),
            fnum(speeds[0] / speeds[3], 2),
        ]);
    }
    t.emit(None);
    println!("full sweep: {}", csv_path.display());

    // the figure's point: relative speed is NOT constant across the range —
    // hcl01 (3.4 GHz P4, 800 MHz bus) vs hcl09 (1.8 GHz Opteron, 1 GHz bus)
    // even *cross over*: the P4 wins in cache, the Opteron wins in RAM
    let rel = |x: f64| models[0].1.speed(x) / models[1].1.speed(x);
    let (r_cache, r_mem) = (rel(2e3), rel(5e6));
    println!("\nrelative speed hcl01/hcl09: {r_cache:.2} in cache vs {r_mem:.2} in RAM");
    assert!(
        (r_cache - r_mem).abs() / r_mem > 0.15,
        "relative speeds should differ across regimes: {r_cache:.2} vs {r_mem:.2}"
    );
    println!("shape check passed: constant-performance models cannot capture this");
}
