//! Ablation studies of the design choices DESIGN.md calls out:
//!
//! 1. the nested 2D algorithm's optimizations (paper §3.2's last
//!    paragraphs): width freezing, benchmark time-capping, warm starts —
//!    each toggled off against the full configuration;
//! 2. oscillation-aware width damping (this repo's addition) on/off;
//! 3. DFPA vs the *dynamic* task-queue baseline (weighted factoring,
//!    refs [11]/[2]) on the 1D application;
//! 4. adaptive (ref [19]) vs uniform-grid full-model construction.

use hfpm::apps::matmul1d::{build_cluster, Matmul1dConfig, RowBench, Strategy};
use hfpm::baselines::factoring::{run_factoring, Weighting};
use hfpm::cluster::comm::CommModel;
use hfpm::cluster::executor::NodeExecutor;
use hfpm::cluster::node::build_nodes;
use hfpm::cluster::presets;
use hfpm::cluster::virtual_cluster::{VirtualCluster, VirtualCluster2d};
use hfpm::dfpa::{run_dfpa, DfpaOptions};
use hfpm::dfpa2d::{run_dfpa2d, Dfpa2dOptions};
use hfpm::fpm::analytic::Footprint;
use hfpm::fpm::builder::{build_adaptive_model, build_exact_models, log_grid};
use hfpm::fpm::SpeedFunction;
use hfpm::util::table::{fnum, Table};

fn grid2d(n_elems: u64) -> VirtualCluster2d {
    let spec = presets::hcl();
    let m = n_elems / 32;
    let fp = Footprint::matmul_2d(32, (m / 4) as usize);
    let nodes = build_nodes(&spec, fp, 32);
    let execs: Vec<Box<dyn NodeExecutor>> = nodes
        .into_iter()
        .map(|n| Box::new(n) as Box<dyn NodeExecutor>)
        .collect();
    let cluster = VirtualCluster::spawn(execs, CommModel::new(spec), Default::default());
    VirtualCluster2d::new(cluster, 4, 4).unwrap()
}

fn main() {
    let n_elems = 14336u64; // paging-borderline size: optimizations matter
    let m = n_elems / 32;

    // --- 1+2: nested-2D optimization ablation ---
    let mut t = Table::new(
        &format!("2D DFPA ablation (HCL 16 nodes, N = {n_elems})"),
        &["configuration", "inner iters", "DFPA cost (s)", "imbalance %"],
    );
    let variants: Vec<(&str, Dfpa2dOptions)> = vec![
        ("full (all optimizations)", Dfpa2dOptions::with_epsilon(0.1)),
        ("no width freezing", {
            let mut o = Dfpa2dOptions::with_epsilon(0.1);
            o.width_freeze_rel = 0.0;
            o
        }),
        ("no benchmark time-cap", {
            let mut o = Dfpa2dOptions::with_epsilon(0.1);
            o.time_cap_mult = None;
            o
        }),
        ("loose inner ε (0.3)", {
            let mut o = Dfpa2dOptions::with_epsilon(0.1);
            o.epsilon_inner = 0.3;
            o
        }),
    ];
    let mut full_cost = None;
    for (label, opts) in variants {
        let mut grid = grid2d(n_elems);
        let r = run_dfpa2d(m, m, &mut grid, opts).expect("2d run");
        if full_cost.is_none() {
            full_cost = Some(r.total_virtual_s);
        }
        t.add_row(vec![
            label.to_string(),
            r.inner_iterations.to_string(),
            fnum(r.total_virtual_s, 2),
            fnum(100.0 * r.imbalance, 1),
        ]);
    }
    t.emit(Some(std::path::Path::new("results/bench/ablation_2d.csv")));

    // --- 3: DFPA vs dynamic weighted factoring on the 1D app ---
    let spec = presets::hcl15();
    let n = 5120u64;
    let mut t = Table::new(
        &format!("1D: DFPA vs dynamic task-queue baselines (n = {n})"),
        &["scheduler", "total virtual (s)", "rounds/iters"],
    );
    {
        let cfg = Matmul1dConfig::new(n, Strategy::Dfpa);
        let (mut cluster, _) = build_cluster(&spec, &cfg, Default::default()).unwrap();
        let mut bench = RowBench {
            cluster: &mut cluster,
            n,
        };
        let r = run_dfpa(n, &mut bench, DfpaOptions::with_epsilon(0.025)).unwrap();
        // DFPA's cost = discovery + ONE balanced full execution. A full
        // multiplication is n kernel steps at the final distribution.
        let exec = r
            .times
            .iter()
            .cloned()
            .fold(0.0f64, f64::max)
            * n as f64;
        t.add_row(vec![
            "DFPA (discover, then static optimal)".into(),
            fnum(r.total_virtual_s + exec, 2),
            r.iterations.to_string(),
        ]);
        for (label, weighting) in [
            ("weighted factoring, static [11]", Weighting::Static),
            ("weighted factoring, adaptive [2]", Weighting::Adaptive),
        ] {
            let cfg = Matmul1dConfig::new(n, Strategy::Dfpa);
            let (mut cluster, _) = build_cluster(&spec, &cfg, Default::default()).unwrap();
            let mut bench = RowBench {
                cluster: &mut cluster,
                n,
            };
            // factoring schedules ROWS of the full multiplication; each
            // round's kernel is a full n-step multiply of its chunk, so
            // scale the per-round benchmark time accordingly
            let out = run_factoring(n, &mut bench, 0.5, weighting).unwrap();
            t.add_row(vec![
                label.into(),
                fnum(out.total_s * n as f64, 2),
                out.rounds.to_string(),
            ]);
        }
    }
    t.emit(Some(std::path::Path::new("results/bench/ablation_sched.csv")));

    // --- 4: adaptive vs uniform full-model construction ---
    let spec_node = presets::hcl().nodes[10].clone(); // hcl11
    let truth = hfpm::fpm::analytic::AnalyticModel::from_spec(
        &spec_node,
        Footprint::affine(16.0, 0.0),
    );
    let mut t = Table::new(
        "full-FPM construction: uniform grid [16] vs adaptive bisection [19]",
        &["method", "points", "build cost (s)", "max rel err %"],
    );
    let probe = log_grid(1e3, 1e8, 300);
    let max_err = |model: &hfpm::fpm::PiecewiseModel| -> f64 {
        probe
            .iter()
            .map(|&x| (model.speed(x) - truth.speed(x)).abs() / truth.speed(x))
            .fold(0.0f64, f64::max)
    };
    {
        let grid = log_grid(1e3, 1e8, 40);
        let (models, cost) = build_exact_models(&[truth.clone()], &grid);
        t.add_row(vec![
            "uniform 40-pt grid".into(),
            cost.points_per_proc.to_string(),
            fnum(cost.parallel_s, 2),
            fnum(100.0 * max_err(&models[0]), 1),
        ]);
    }
    {
        let (model, cost) = build_adaptive_model(1e3, 1e8, 0.05, 64, |x| truth.time(x));
        t.add_row(vec![
            "adaptive (ref [19], tol 5%)".into(),
            cost.points_per_proc.to_string(),
            fnum(cost.parallel_s, 2),
            fnum(100.0 * max_err(&model), 1),
        ]);
    }
    t.emit(Some(std::path::Path::new("results/bench/ablation_builder.csv")));
}
