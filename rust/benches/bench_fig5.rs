//! Regenerates **Fig 5**: (a) the absolute speed of hcl11 as a 2D function
//! of task size (x, y); (b) the relative speed of hcl09/hcl06 over the
//! same grid — the paper's evidence that one constant cannot describe the
//! ratio of two heterogeneous processors.

use hfpm::cluster::presets;
use hfpm::fpm::SpeedSurface;
use hfpm::util::csv::CsvWriter;
use std::path::Path;

fn main() {
    let spec = presets::hcl();
    let node = |h: &str| spec.nodes.iter().find(|n| n.host == h).unwrap();
    let s11 = SpeedSurface::from_spec(node("hcl11"), 32);
    let s09 = SpeedSurface::from_spec(node("hcl09"), 32);
    let s06 = SpeedSurface::from_spec(node("hcl06"), 32);

    // (a) hcl11 speed surface
    let path_a = Path::new("results/bench/fig5a_hcl11_surface.csv");
    let mut csv_a = CsvWriter::create(path_a, &["x_blocks", "y_blocks", "speed_Mu_s"]).unwrap();
    let axis: Vec<f64> = (0..24).map(|i| 8.0 * 1.35f64.powi(i)).collect();
    for &x in &axis {
        for &y in &axis {
            csv_a.row_f64(&[x, y, s11.speed(x, y) / 1e6], 3).unwrap();
        }
    }
    csv_a.flush().unwrap();

    // (b) relative speed hcl09 / hcl06
    let path_b = Path::new("results/bench/fig5b_rel_hcl09_hcl06.csv");
    let mut csv_b = CsvWriter::create(path_b, &["x_blocks", "y_blocks", "relative"]).unwrap();
    let mut rel_min = f64::MAX;
    let mut rel_max = f64::MIN;
    for &x in &axis {
        for &y in &axis {
            let r = s09.speed(x, y) / s06.speed(x, y);
            rel_min = rel_min.min(r);
            rel_max = rel_max.max(r);
            csv_b.row_f64(&[x, y, r], 4).unwrap();
        }
    }
    csv_b.flush().unwrap();

    println!("Fig 5a surface: {}", path_a.display());
    println!("Fig 5b relative-speed surface: {}", path_b.display());
    println!(
        "\nrelative speed hcl09/hcl06 varies over [{rel_min:.2}, {rel_max:.2}] across the grid"
    );
    // the figure's point: the ratio varies significantly with (x, y)
    assert!(
        rel_max / rel_min > 1.3,
        "relative speed should vary significantly: {rel_min:.2}..{rel_max:.2}"
    );
    println!("shape check passed: the ratio is far from constant (paper: 'varies significantly')");
}
