//! Regenerates **Fig 9**: (a) absolute 2D speed surfaces g_i(x, y) of
//! three processors; (b) their 1D projections at fixed column widths
//! x = 1.22, 2.02, 2.64 ×10⁴ — the projections the nested 2D algorithm
//! feeds to DFPA.

use hfpm::cluster::presets;
use hfpm::fpm::{SpeedFunction, SpeedSurface};
use hfpm::util::csv::CsvWriter;
use std::path::Path;

fn main() {
    let spec = presets::hcl();
    let hosts = ["hcl01", "hcl09", "hcl13"];
    let surfaces: Vec<(String, SpeedSurface)> = hosts
        .iter()
        .map(|h| {
            let nd = spec.nodes.iter().find(|n| &n.host == h).unwrap();
            (h.to_string(), SpeedSurface::from_spec(nd, 32))
        })
        .collect();

    // (a) surfaces
    let path_a = Path::new("results/bench/fig9a_surfaces.csv");
    let mut csv = CsvWriter::create(path_a, &["host", "x", "y", "speed_Mu_s"]).unwrap();
    let axis: Vec<f64> = (0..20).map(|i| 8.0 * 1.4f64.powi(i)).collect();
    for (host, s) in &surfaces {
        for &x in &axis {
            for &y in &axis {
                csv.row(&[
                    host.clone(),
                    format!("{x:.1}"),
                    format!("{y:.1}"),
                    format!("{:.3}", s.speed(x, y) / 1e6),
                ])
                .unwrap();
            }
        }
    }
    csv.flush().unwrap();

    // (b) projections at the paper's fixed widths (block-units here)
    let widths = [38.0, 63.0, 83.0]; // ≈ the paper's 1.22/2.02/2.64e4 elems / 32² per block ratio
    let path_b = Path::new("results/bench/fig9b_projections.csv");
    let mut csv = CsvWriter::create(path_b, &["host", "width", "units", "speed_Mu_s"]).unwrap();
    for (host, s) in &surfaces {
        for &w in &widths {
            let proj = s.project(w);
            for i in 1..=40 {
                let units = i as f64 * w * 50.0;
                csv.row(&[
                    host.clone(),
                    format!("{w:.0}"),
                    format!("{units:.0}"),
                    format!("{:.3}", proj.speed(units) / 1e6),
                ])
                .unwrap();
            }
        }
    }
    csv.flush().unwrap();

    println!("Fig 9a surfaces: {}", path_a.display());
    println!("Fig 9b projections: {}", path_b.display());

    // consistency: each projection is an exact slice of its surface
    for (host, s) in &surfaces {
        let proj = s.project(63.0);
        for x in [10.0, 100.0, 1000.0] {
            let via_proj = proj.speed(x * 63.0);
            let via_surf = s.speed(x, 63.0);
            assert!(
                (via_proj - via_surf).abs() < 1e-9 * via_surf.max(1.0),
                "{host}: projection inconsistent at x={x}"
            );
        }
    }
    println!("\nconsistency check passed: projections are exact surface slices");
}
