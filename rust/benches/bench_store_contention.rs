//! Concurrent model-store contention: the single-writer service against
//! N sessions racing the advisory lock directly.
//!
//! Every point runs K concurrent sessions, each recording `RUNS`
//! observation batches for its own key, twice:
//!
//! - **direct** — each session opens its own `ModelStore` on the shared
//!   directory. Only one wins the advisory `.hfpm.lock`; every other
//!   session's saves are warn-and-skipped (counted in `dropped_saves`) and
//!   its observations never reach disk.
//! - **service** — all sessions share one [`StoreService`] handle and
//!   submit batches to its writer thread. The bounded channel blocks
//!   instead of dropping; `dropped_saves` must be **zero** at every K (the
//!   zero-drop guarantee — hard-asserted, not strict-gated).
//!
//! Throughput (observation batches per second) and the drop counts land in
//! `BENCH_store.json`.
//!
//! Env knobs:
//! - `BENCH_STORE_SESSIONS="1,8"` — override the session counts (CI smoke);
//! - `BENCH_STORE_RUNS=32` — batches per session;
//! - `BENCH_STORE_OUT=path.json` — where to write the results
//!   (default `BENCH_store.json` in the cargo cwd, i.e. `rust/`).

use hfpm::modelstore::{
    Family, MergePolicy, ModelKey, ModelStore, ObsBatch, StoreService, StoreServiceConfig,
};
use hfpm::fpm::PiecewiseModel;
use hfpm::testkit::unique_temp_dir;
use hfpm::util::table::{fnum, Table};
use hfpm::util::timer::Stopwatch;
use std::sync::Barrier;

fn key_for(session: usize) -> ModelKey {
    ModelKey::new(&format!("node{session:03}"), "bench_contention", "sim")
}

/// One session's observed partial model for run `r`: a couple of points at
/// sizes distinct per run so merges insert rather than blend.
fn observation(session: usize, r: usize) -> PiecewiseModel {
    let mut m = PiecewiseModel::new();
    let base = 100.0 + r as f64 * 64.0;
    m.insert(base, 5.0 + session as f64);
    m.insert(base + 32.0, 6.0 + session as f64);
    m
}

struct Point {
    sessions: usize,
    runs: usize,
    direct_wall_s: f64,
    direct_obs_per_s: f64,
    direct_dropped: u64,
    direct_persisted: usize,
    service_wall_s: f64,
    service_obs_per_s: f64,
    service_dropped: u64,
    service_persisted: usize,
}

/// K sessions, each its own `ModelStore` on one directory: the legacy
/// pattern the service replaces. Returns (wall, dropped saves, keys on disk).
fn run_direct(k: usize, runs: usize) -> (f64, u64, usize) {
    let dir = unique_temp_dir("bench-store-direct");
    let barrier = Barrier::new(k);
    let sw = Stopwatch::start();
    let dropped: u64 = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..k)
            .map(|s| {
                let dir = dir.clone();
                let barrier = &barrier;
                scope.spawn(move || {
                    let store = ModelStore::open(&dir).expect("open store").quiet(true);
                    let key = key_for(s);
                    barrier.wait();
                    for r in 0..runs {
                        store
                            .record_run(
                                std::slice::from_ref(&key),
                                &[observation(s, r)],
                                &MergePolicy::default(),
                            )
                            .expect("record_run");
                    }
                    store.stats().dropped_saves
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("session")).sum()
    });
    let wall = sw.elapsed_s();
    let persisted = ModelStore::open(&dir)
        .expect("reopen")
        .entries()
        .expect("entries")
        .len();
    let _ = std::fs::remove_dir_all(&dir);
    (wall, dropped, persisted)
}

/// K sessions sharing one service handle. Returns (wall, dropped saves,
/// keys on disk); wall includes the final flush, so everything is durable.
fn run_service(k: usize, runs: usize) -> (f64, u64, usize) {
    let dir = unique_temp_dir("bench-store-service");
    let handle = StoreService::open_with(
        &dir,
        StoreServiceConfig {
            quiet: true,
            ..Default::default()
        },
    )
    .expect("open service");
    let barrier = Barrier::new(k);
    let sw = Stopwatch::start();
    std::thread::scope(|scope| {
        for s in 0..k {
            let handle = handle.clone();
            let barrier = &barrier;
            scope.spawn(move || {
                let key = key_for(s);
                barrier.wait();
                for r in 0..runs {
                    let mut b = ObsBatch::new();
                    b.insert(key.clone(), Family::Speed, observation(s, r));
                    handle.submit(b).expect("submit");
                }
            });
        }
    });
    let stats = handle.flush().expect("flush");
    let wall = sw.elapsed_s();
    assert_eq!(
        stats.dropped_saves, 0,
        "zero-drop guarantee violated at {k} sessions: {stats:?}"
    );
    assert_eq!(
        stats.merged_batches,
        (k * runs) as u64,
        "every submitted batch must merge"
    );
    drop(handle);
    let persisted = ModelStore::open(&dir)
        .expect("reopen")
        .entries()
        .expect("entries")
        .len();
    let _ = std::fs::remove_dir_all(&dir);
    (wall, stats.dropped_saves, persisted)
}

fn run_point(k: usize, runs: usize) -> Point {
    let obs = (k * runs) as f64;
    let (direct_wall_s, direct_dropped, direct_persisted) = run_direct(k, runs);
    let (service_wall_s, service_dropped, service_persisted) = run_service(k, runs);
    // the service must persist every session's key; the direct path
    // persists only the lock holder's
    assert_eq!(service_persisted, k, "one model per session on disk");
    Point {
        sessions: k,
        runs,
        direct_wall_s,
        direct_obs_per_s: obs / direct_wall_s.max(f64::MIN_POSITIVE),
        direct_dropped,
        direct_persisted,
        service_wall_s,
        service_obs_per_s: obs / service_wall_s.max(f64::MIN_POSITIVE),
        service_dropped,
        service_persisted,
    }
}

fn json(points: &[Point]) -> String {
    let mut out = String::from("{\n  \"bench\": \"bench_store_contention\",\n  \"points\": [\n");
    for (i, p) in points.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"sessions\": {}, \"runs\": {}, \
             \"direct_obs_per_s\": {:.1}, \"direct_dropped\": {}, \"direct_persisted\": {}, \
             \"service_obs_per_s\": {:.1}, \"service_dropped\": {}, \"service_persisted\": {}}}{}\n",
            p.sessions,
            p.runs,
            p.direct_obs_per_s,
            p.direct_dropped,
            p.direct_persisted,
            p.service_obs_per_s,
            p.service_dropped,
            p.service_persisted,
            if i + 1 < points.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

fn main() {
    let counts: Vec<usize> = match std::env::var("BENCH_STORE_SESSIONS") {
        Ok(v) => v
            .split(',')
            .map(|s| s.trim().parse().expect("BENCH_STORE_SESSIONS: bad count"))
            .collect(),
        Err(_) => vec![1, 4, 16, 64],
    };
    let runs: usize = std::env::var("BENCH_STORE_RUNS")
        .ok()
        .map(|v| v.parse().expect("BENCH_STORE_RUNS: bad count"))
        .unwrap_or(32);

    let mut t = Table::new(
        &format!("model-store contention ({runs} batches per session)"),
        &[
            "sessions", "direct obs/s", "dropped", "persisted", "service obs/s", "dropped",
            "persisted",
        ],
    );
    let mut points = Vec::new();
    for &k in &counts {
        let p = run_point(k, runs);
        t.add_row(vec![
            p.sessions.to_string(),
            fnum(p.direct_obs_per_s, 0),
            p.direct_dropped.to_string(),
            p.direct_persisted.to_string(),
            fnum(p.service_obs_per_s, 0),
            p.service_dropped.to_string(),
            p.service_persisted.to_string(),
        ]);
        points.push(p);
    }
    print!("{}", t.render());
    println!(
        "wall: direct {:?}, service {:?}",
        points.iter().map(|p| p.direct_wall_s).collect::<Vec<_>>(),
        points.iter().map(|p| p.service_wall_s).collect::<Vec<_>>()
    );

    let out = std::env::var("BENCH_STORE_OUT").unwrap_or_else(|_| "BENCH_store.json".into());
    std::fs::write(&out, json(&points)).expect("write BENCH_store.json");
    println!("json: {out}");
}
