//! Tracing overhead: the same jacobi workload with the obs sink disabled
//! vs enabled (full session + engine + store-service instrumentation).
//!
//! The obs layer's contract is "observable for free": the hot path never
//! blocks (`try_lock`, counted drops) and the disabled sink is a single
//! branch. This bench measures the enabled-vs-disabled wall-time ratio
//! over min-of-N runs and **hard-asserts** two bounds:
//!
//! - overhead < 5% (the ISSUE acceptance bound, with a small absolute
//!   floor so micro-jitter on a fast machine cannot fail the lane);
//! - zero *silent* loss — every drop the sink takes is counted, i.e.
//!   `emitted == recorded + dropped` on the final summary.
//!
//! Results land in `BENCH_obs.json`.
//!
//! Env knobs:
//! - `BENCH_OBS_RUNS=5` — samples per side (min is reported);
//! - `BENCH_OBS_N=1024` — jacobi problem size;
//! - `BENCH_OBS_OUT=path.json` — output path (default `BENCH_obs.json`
//!   in the cargo cwd, i.e. `rust/`).

use hfpm::adapt::Strategy;
use hfpm::apps::jacobi;
use hfpm::cluster::presets;
use hfpm::obs::{ObsSink, ObsSummary, DEFAULT_CAPACITY};
use hfpm::util::table::{fdur, fnum, Table};
use hfpm::util::timer::Stopwatch;

fn run_once(n: u64, sink: &ObsSink) -> f64 {
    let spec = presets::mini4();
    let mut cfg = jacobi::JacobiConfig::new(n, Strategy::Dfpa);
    cfg.sweeps = 8;
    cfg.rebalance_every = 2;
    cfg.obs = sink.clone();
    let sw = Stopwatch::start();
    jacobi::run(&spec, &cfg).expect("jacobi run");
    sw.elapsed_s()
}

/// Min-of-N wall time; min (not mean) because scheduler noise only ever
/// adds time, so the minimum is the cleanest overhead estimator.
fn min_of(runs: usize, mut f: impl FnMut() -> f64) -> f64 {
    (0..runs).map(|_| f()).fold(f64::INFINITY, f64::min)
}

fn main() {
    let runs: usize = std::env::var("BENCH_OBS_RUNS")
        .ok()
        .map(|v| v.parse().expect("BENCH_OBS_RUNS: bad count"))
        .unwrap_or(5);
    let n: u64 = std::env::var("BENCH_OBS_N")
        .ok()
        .map(|v| v.parse().expect("BENCH_OBS_N: bad size"))
        .unwrap_or(1024);

    // warm-up: page in code paths and the allocator before timing
    let _ = run_once(n, &ObsSink::disabled());

    let off_s = min_of(runs, || run_once(n, &ObsSink::disabled()));

    let mut last_summary: Option<ObsSummary> = None;
    let on_s = min_of(runs, || {
        let sink = ObsSink::bounded(DEFAULT_CAPACITY);
        let wall = run_once(n, &sink);
        last_summary = sink.summary();
        wall
    });
    let summary = last_summary.expect("enabled sink has a summary");

    let overhead = on_s / off_s.max(f64::MIN_POSITIVE) - 1.0;
    let mut t = Table::new(
        &format!("obs overhead (jacobi n={n}, min of {runs})"),
        &["sink", "wall", "events", "dropped", "overhead %"],
    );
    t.add_row(vec![
        "disabled".into(),
        fdur(off_s),
        "0".into(),
        "0".into(),
        "-".into(),
    ]);
    t.add_row(vec![
        "enabled".into(),
        fdur(on_s),
        summary.recorded.to_string(),
        summary.dropped.to_string(),
        fnum(100.0 * overhead, 2),
    ]);
    print!("{}", t.render());

    let out = std::env::var("BENCH_OBS_OUT").unwrap_or_else(|_| "BENCH_obs.json".into());
    std::fs::write(
        &out,
        format!(
            "{{\n  \"bench\": \"bench_obs\",\n  \"n\": {n},\n  \"runs\": {runs},\n  \
             \"disabled_wall_s\": {off_s:.6},\n  \"enabled_wall_s\": {on_s:.6},\n  \
             \"overhead_pct\": {:.3},\n  \"emitted\": {},\n  \"recorded\": {},\n  \
             \"dropped\": {}\n}}\n",
            100.0 * overhead,
            summary.emitted,
            summary.recorded,
            summary.dropped
        ),
    )
    .expect("write BENCH_obs.json");
    println!("json: {out}");

    // no silent loss: the sink's books must balance exactly
    assert_eq!(
        summary.emitted,
        summary.recorded + summary.dropped,
        "loss accounting must be exact: {summary:?}"
    );
    // <5% overhead, with a 2ms absolute floor: on a machine where the
    // whole run takes a few ms, a scheduler blip is not an obs regression
    let excess_s = (on_s - off_s).max(0.0);
    assert!(
        overhead < 0.05 || excess_s < 2e-3,
        "tracing overhead {:.2}% (|{}|) exceeds the 5% bound",
        100.0 * overhead,
        fdur(excess_s)
    );
    println!(
        "overhead {:.2}% — within the 5% bound",
        100.0 * overhead
    );
}
