//! Regenerates the **model-construction cost comparison** quoted with
//! Table 2: building the full FPMs of 15 processors took the paper 1850 s
//! over a 160-point grid, while DFPA converged with ≤ 11 in-band points —
//! orders of magnitude cheaper. Also sweeps the grid density to show how
//! full-model cost scales with the number of experimental points (the
//! paper's argument that more problem-size parameters make full models
//! combinatorially expensive).

use hfpm::apps::matmul1d::{build_cluster, Matmul1dConfig, RowBench, Strategy};
use hfpm::baselines::ffmpa;
use hfpm::cluster::node::build_nodes;
use hfpm::cluster::presets;
use hfpm::dfpa::{run_dfpa, DfpaOptions};
use hfpm::fpm::analytic::Footprint;
use hfpm::util::table::{fnum, Table};

fn main() {
    let spec = presets::hcl15();

    // full-model construction cost across grid densities
    let mut t = Table::new(
        "full-FPM construction cost vs grid density (15 HCL nodes)",
        &["points/proc", "parallel build (s)", "serial build (s)"],
    );
    let nodes = build_nodes(&spec, Footprint::matmul_1d(8192), 32);
    for density in [1u64, 2, 4, 8] {
        // take every `8/density`-th n value of the paper grid
        let mut total = hfpm::fpm::builder::BuildCost::default();
        let mut n = 1024u64;
        let step = 8192 / density.min(8) / 1024;
        while n <= 8192 {
            let fp = Footprint::matmul_1d(n as usize);
            let truths: Vec<_> = nodes.iter().map(|nd| nd.truth().with_footprint(fp)).collect();
            for &x in &ffmpa::grid_for_n(n) {
                use hfpm::fpm::SpeedFunction;
                let times: Vec<f64> = truths.iter().map(|m| m.time(x)).collect();
                total.serial_s += times.iter().sum::<f64>();
                total.parallel_s += times.iter().cloned().fold(0.0f64, f64::max);
                total.points_per_proc += 1;
            }
            n += step.max(1) * 1024;
        }
        t.add_row(vec![
            total.points_per_proc.to_string(),
            fnum(total.parallel_s, 1),
            fnum(total.serial_s, 1),
        ]);
    }
    t.emit(Some(std::path::Path::new("results/bench/model_build.csv")));

    // DFPA's in-band cost for the same platform
    let mut t2 = Table::new(
        "DFPA in-band cost (ε = 2.5%)",
        &["n", "DFPA (s)", "points/proc"],
    );
    let mut worst_dfpa = 0.0f64;
    for n in [2048u64, 5120, 8192] {
        let cfg = Matmul1dConfig::new(n, Strategy::Dfpa);
        let (mut cluster, _) = build_cluster(&spec, &cfg, Default::default()).unwrap();
        let mut bench = RowBench {
            cluster: &mut cluster,
            n,
        };
        let r = run_dfpa(n, &mut bench, DfpaOptions::with_epsilon(0.025)).unwrap();
        worst_dfpa = worst_dfpa.max(r.total_virtual_s);
        t2.add_row(vec![
            n.to_string(),
            fnum(r.total_virtual_s, 3),
            r.points_per_processor().to_string(),
        ]);
    }
    t2.emit(None);

    let full = ffmpa::full_grid_build_cost(&nodes, 8192);
    let factor = full.parallel_s / worst_dfpa.max(1e-9);
    println!(
        "\nfull build {:.1}s vs worst DFPA {:.3}s → {:.0}× cheaper (paper: 1850s vs ~29s, ~64×;",
        full.parallel_s, worst_dfpa, factor
    );
    println!("vs cheap-size DFPA runs the gap is orders of magnitude, as claimed)");
    assert!(factor > 10.0, "DFPA must be ≫ cheaper than the full build");
}
