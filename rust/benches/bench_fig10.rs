//! Regenerates **Fig 10**: total execution time of the heterogeneous 2D
//! matmul with CPM-based, FFMPA-based and DFPA-based partitioning on 16
//! HCL nodes, across matrix sizes. The paper's shape: FFMPA best (models
//! pre-built), DFPA close behind, CPM ~25% slower due to the less
//! accurate distribution.

use hfpm::apps::matmul2d::{run, Matmul2dConfig};
use hfpm::apps::Strategy;
use hfpm::cluster::presets;
use hfpm::util::csv::CsvWriter;
use hfpm::util::table::{fnum, Table};
use std::path::Path;

fn main() {
    let spec = presets::hcl();
    let sizes: Vec<u64> = vec![10240, 12288, 14336, 16384, 19456];
    let mut t = Table::new(
        "Fig 10 — 2D matmul times (s) by partitioning strategy, 16 HCL nodes",
        &["n", "CPM mm", "FFMPA mm", "DFPA mm", "DFPA total", "CPM/DFPA mm"],
    );
    let csv_path = Path::new("results/bench/fig10.csv");
    let mut csv =
        CsvWriter::create(csv_path, &["n", "cpm_mm_s", "ffmpa_mm_s", "dfpa_mm_s", "dfpa_total_s"])
            .unwrap();
    let mut slowdowns = Vec::new();
    for &n in &sizes {
        let run_r = |strategy: Strategy| {
            let mut cfg = Matmul2dConfig::new(n, strategy);
            cfg.epsilon = 0.1;
            run(&spec, &cfg).expect("2d run")
        };
        let cpm = run_r(Strategy::Cpm);
        let ffmpa = run_r(Strategy::Ffmpa);
        let dfpa = run_r(Strategy::Dfpa);
        slowdowns.push(cpm.matmul_s / dfpa.matmul_s);
        t.add_row(vec![
            n.to_string(),
            fnum(cpm.matmul_s, 2),
            fnum(ffmpa.matmul_s, 2),
            fnum(dfpa.matmul_s, 2),
            fnum(dfpa.total_s, 2),
            fnum(cpm.matmul_s / dfpa.matmul_s, 3),
        ]);
        csv.row_f64(
            &[n as f64, cpm.matmul_s, ffmpa.matmul_s, dfpa.matmul_s, dfpa.total_s],
            3,
        )
        .unwrap();
        // ordering shape (on the multiplication itself, which is what the
        // distribution quality controls): FFMPA ≤ DFPA ≤ CPM, with slack —
        // in non-paging regimes all three can tie
        assert!(
            ffmpa.matmul_s <= dfpa.matmul_s * 1.15,
            "n={n}: FFMPA ({:.1}) should not trail DFPA ({:.1}) by >15%",
            ffmpa.matmul_s,
            dfpa.matmul_s
        );
        assert!(
            dfpa.matmul_s <= cpm.matmul_s * 1.05,
            "n={n}: DFPA matmul ({:.1}) must not lose to CPM ({:.1})",
            dfpa.matmul_s,
            cpm.matmul_s
        );
    }
    csv.flush().unwrap();
    t.emit(None);
    let mean_slow: f64 = slowdowns.iter().sum::<f64>() / slowdowns.len() as f64;
    println!("csv: {}", csv_path.display());
    println!(
        "\nCPM is on average {:.0}% slower than DFPA (paper: ~25%)",
        100.0 * (mean_slow - 1.0)
    );
    assert!(
        mean_slow > 1.05,
        "CPM should trail DFPA on average once paging sizes are included ({mean_slow:.3})"
    );
}
