//! Integration tests: the virtual cluster runtime (protocol, accounting,
//! determinism).

use hfpm::cluster::comm::CommModel;
use hfpm::cluster::executor::NodeExecutor;
use hfpm::cluster::faults::FaultPlan;
use hfpm::cluster::node::build_nodes;
use hfpm::cluster::presets;
use hfpm::cluster::virtual_cluster::VirtualCluster;
use hfpm::fpm::analytic::Footprint;

fn spawn(preset: &str) -> VirtualCluster {
    let spec = presets::by_name(preset).unwrap();
    let nodes = build_nodes(&spec, Footprint::matmul_1d(2048), 32);
    let execs: Vec<Box<dyn NodeExecutor>> = nodes
        .into_iter()
        .map(|n| Box::new(n) as Box<dyn NodeExecutor>)
        .collect();
    VirtualCluster::spawn(execs, CommModel::new(spec), FaultPlan::none())
}

#[test]
fn full_hcl_superstep() {
    let mut c = spawn("hcl");
    let d = vec![100_000u64; 16];
    let r = c.run_1d(&d).unwrap();
    assert_eq!(r.times.len(), 16);
    assert!(r.times.iter().all(|&t| t > 0.0));
    // step cost ≥ slowest worker
    let max = r.times.iter().cloned().fold(0.0f64, f64::max);
    assert!(r.virtual_cost_s >= max);
}

#[test]
fn heterogeneity_visible_in_times() {
    let mut c = spawn("hcl");
    let d = vec![500_000u64; 16];
    let r = c.run_1d(&d).unwrap();
    let min = r.times.iter().cloned().fold(f64::MAX, f64::min);
    let max = r.times.iter().cloned().fold(0.0f64, f64::max);
    // peak heterogeneity ≈ 2 on HCL
    assert!(max / min > 1.3, "ratio {}", max / min);
    assert!(max / min < 4.0, "ratio {}", max / min);
}

#[test]
fn virtual_time_is_deterministic_across_runs() {
    let run = || {
        let mut c = spawn("mini4");
        c.run_1d(&[10_000, 20_000, 30_000, 40_000]).unwrap();
        c.run_1d(&[40_000, 30_000, 20_000, 10_000]).unwrap();
        c.now()
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "virtual clock must be reproducible");
}

#[test]
fn steps_counted() {
    let mut c = spawn("mini4");
    assert_eq!(c.steps_run, 0);
    c.run_1d(&[1, 1, 1, 1]).unwrap();
    c.run_1d(&[1, 1, 1, 1]).unwrap();
    assert_eq!(c.steps_run, 2);
}

#[test]
fn grid5000_wan_collectives_cost_more() {
    let g5k = presets::grid5000();
    let hcl = presets::hcl();
    let m_g5k = CommModel::new(g5k);
    let m_hcl = CommModel::new(hcl);
    // control traffic crossing sites costs much more than LAN-only
    assert!(m_g5k.dfpa_iteration_cost(0) > 5.0 * m_hcl.dfpa_iteration_cost(0));
}

#[test]
fn charge_accumulates_into_clock() {
    let mut c = spawn("mini4");
    let t0 = c.now();
    c.charge(12.5);
    assert!((c.now() - t0 - 12.5).abs() < 1e-12);
}

#[test]
fn many_supersteps_stay_consistent() {
    // stress the leader/worker protocol: 200 supersteps with varying work
    let mut c = spawn("mini4");
    let mut last = 0.0;
    for k in 1..=200u64 {
        let r = c.run_1d(&[k * 10, k * 20, k * 5, k * 15]).unwrap();
        assert_eq!(r.times.len(), 4);
        assert!(c.now() > last);
        last = c.now();
    }
    assert_eq!(c.steps_run, 200);
}
