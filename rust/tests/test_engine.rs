//! Integration tests: the frame-synchronized engine against the legacy
//! thread-per-node runtime — accounting parity, fault surfacing, and
//! fault visibility in the adaptive layer above it.

use hfpm::cluster::comm::CommModel;
use hfpm::cluster::executor::NodeExecutor;
use hfpm::cluster::faults::FaultPlan;
use hfpm::cluster::node::build_nodes;
use hfpm::cluster::presets;
use hfpm::cluster::{Engine, LegacyCluster};
use hfpm::dfpa::{run_dfpa, DfpaOptions, DfpaResult};
use hfpm::error::HfpmError;
use hfpm::fpm::analytic::Footprint;

fn executors(preset: &str) -> (Vec<Box<dyn NodeExecutor>>, CommModel) {
    let spec = presets::by_name(preset).unwrap();
    let nodes = build_nodes(&spec, Footprint::matmul_1d(2048), 32);
    let execs = nodes
        .into_iter()
        .map(|n| Box::new(n) as Box<dyn NodeExecutor>)
        .collect();
    (execs, CommModel::new(spec))
}

/// The acceptance bar for the refactor: for a fixed seed the engine and the
/// legacy runtime produce the same virtual times, step by step.
#[test]
fn engine_matches_legacy_virtual_times() {
    let (execs, comm) = executors("mini4");
    let mut engine = Engine::spawn(execs, comm, FaultPlan::none());
    let (execs, comm) = executors("mini4");
    let mut legacy = LegacyCluster::spawn(execs, comm, FaultPlan::none());

    let steps: Vec<Vec<u64>> = vec![
        vec![10_000, 20_000, 30_000, 40_000],
        vec![40_000, 30_000, 20_000, 10_000],
        vec![25_000, 25_000, 25_000, 25_000],
        vec![1, 0, 100_000, 7],
    ];
    for d in &steps {
        let e = engine.run_1d(d).unwrap();
        let l = legacy.run_1d(d).unwrap();
        assert_eq!(e.times, l.times, "per-rank times diverge on step {d:?}");
        assert_eq!(
            e.virtual_cost_s, l.virtual_cost_s,
            "fold diverges on step {d:?}"
        );
    }
    assert_eq!(engine.now(), legacy.now(), "virtual clocks diverge");
    assert_eq!(
        engine.total_energy_j(),
        legacy.total_energy_j(),
        "energy accounting diverges"
    );
}

#[test]
fn engine_parity_holds_with_stragglers() {
    let plan = FaultPlan::none().with_straggler(2, 3.0, 1);
    let (execs, comm) = executors("mini4");
    let mut engine = Engine::spawn(execs, comm, plan.clone());
    let (execs, comm) = executors("mini4");
    let mut legacy = LegacyCluster::spawn(execs, comm, plan);
    for _ in 0..5 {
        let d = [50_000u64; 4];
        let e = engine.run_1d(&d).unwrap();
        let l = legacy.run_1d(&d).unwrap();
        assert_eq!(e.times, l.times);
    }
    assert_eq!(engine.now(), legacy.now());
}

/// A worker death surfaces as `WorkerFailed` on the step it happens —
/// the frame barrier must complete, not hang.
#[test]
fn engine_death_surfaces_without_hanging() {
    let (execs, comm) = executors("mini4");
    let mut engine = Engine::spawn(execs, comm, FaultPlan::none().with_death(2, 1));
    engine.run_1d(&[10_000; 4]).unwrap();
    let err = engine.run_1d(&[10_000; 4]).unwrap_err();
    match err {
        HfpmError::WorkerFailed { rank, .. } => assert_eq!(rank, 2),
        other => panic!("expected WorkerFailed, got {other}"),
    }
    // the engine stays usable for the surviving ranks' accounting: the dead
    // rank keeps failing, it does not wedge the frame protocol
    assert!(engine.run_1d(&[10_000; 4]).is_err());
}

/// A straggler injected at the engine layer must be *visible* to the
/// adaptive layer above: DFPA's learned speed function for the slowed rank
/// drops, and so does its share of the work.
#[test]
fn straggler_shows_in_learned_speed_functions() {
    let run = |plan: FaultPlan| {
        let (execs, comm) = executors("mini4");
        let mut engine = Engine::spawn(execs, comm, plan);
        run_dfpa(4096, &mut engine, DfpaOptions::with_epsilon(0.05)).unwrap()
    };
    let healthy = run(FaultPlan::none());
    let slowed = run(FaultPlan::none().with_straggler(1, 4.0, 0));

    let mean_speed = |r: &DfpaResult, rank: usize| {
        let pts = r.models[rank].points();
        pts.iter().map(|p| p.s).sum::<f64>() / pts.len() as f64
    };
    assert!(
        mean_speed(&slowed, 1) < 0.5 * mean_speed(&healthy, 1),
        "4x straggler barely dented the learned speed: {} vs {}",
        mean_speed(&slowed, 1),
        mean_speed(&healthy, 1)
    );
    assert!(
        slowed.d[1] < healthy.d[1],
        "straggler kept its share: {} !< {}",
        slowed.d[1],
        healthy.d[1]
    );
}

/// Same comparison at a size the legacy runtime was never asked to reach:
/// a synthetic 64-node cluster, both runtimes, identical books.
#[test]
fn parity_on_synthetic_64_nodes() {
    let build = || {
        let spec = presets::synth(64);
        let nodes = build_nodes(&spec, Footprint::affine(16.0, 0.0), 32);
        let execs: Vec<Box<dyn NodeExecutor>> = nodes
            .into_iter()
            .map(|n| Box::new(n) as Box<dyn NodeExecutor>)
            .collect();
        (execs, CommModel::new(spec))
    };
    let d: Vec<u64> = (0..64).map(|i| 10_000 + 1_000 * (i % 5)).collect();
    let (execs, comm) = build();
    let mut engine = Engine::spawn(execs, comm, FaultPlan::none());
    let (execs, comm) = build();
    let mut legacy = LegacyCluster::spawn(execs, comm, FaultPlan::none());
    for _ in 0..3 {
        let e = engine.run_1d(&d).unwrap();
        let l = legacy.run_1d(&d).unwrap();
        assert_eq!(e.times, l.times);
    }
    assert_eq!(engine.now(), legacy.now());
    assert!(engine.worker_threads() <= 64);
}
