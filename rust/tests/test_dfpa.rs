//! Integration tests: DFPA on the full cluster runtime (the paper's §2
//! algorithm end to end on the simulated testbeds).

use hfpm::apps::matmul1d::{build_cluster, Matmul1dConfig, RowBench, Strategy};
use hfpm::cluster::presets;
use hfpm::dfpa::{run_dfpa, DfpaOptions, WarmStart};
use hfpm::fpm::PiecewiseModel;
use hfpm::modelstore::{MergePolicy, ModelKey, ModelStore, StoredModel};

fn dfpa_on(preset: &str, n: u64, eps: f64) -> hfpm::dfpa::DfpaResult {
    let spec = presets::by_name(preset).unwrap();
    let cfg = Matmul1dConfig::new(n, Strategy::Dfpa);
    let (mut cluster, _) = build_cluster(&spec, &cfg, Default::default()).unwrap();
    let mut bench = RowBench {
        cluster: &mut cluster,
        n,
    };
    run_dfpa(n, &mut bench, DfpaOptions::with_epsilon(eps)).unwrap()
}

#[test]
fn converges_on_hcl15_mid_sizes() {
    for n in [2048u64, 3072, 4096] {
        let r = dfpa_on("hcl15", n, 0.025);
        assert!(r.converged, "n={n}: imbalance {}", r.imbalance);
        assert_eq!(r.d.iter().sum::<u64>(), n);
        assert!(
            r.iterations <= 15,
            "n={n}: too many iterations ({})",
            r.iterations
        );
    }
}

#[test]
fn paging_borderline_needs_more_iterations() {
    // the paper's n=5120 case: several nodes sit at the paging borderline
    // and DFPA needs extra iterations to discover the cliff
    let easy = dfpa_on("hcl15", 4096, 0.025);
    let hard = dfpa_on("hcl15", 5120, 0.025);
    assert!(hard.converged);
    assert!(
        hard.iterations >= easy.iterations,
        "paging case ({}) should need at least as many iterations as the easy case ({})",
        hard.iterations,
        easy.iterations
    );
}

#[test]
fn paging_nodes_protected_at_5120() {
    let spec = presets::hcl15();
    let r = dfpa_on("hcl15", 5120, 0.025);
    // the 256 MiB nodes (hcl05, hcl06, hcl08 in the 15-node subset) must
    // receive fewer rows than the 1 GiB nodes
    let small: Vec<usize> = spec
        .nodes
        .iter()
        .enumerate()
        .filter(|(_, nd)| nd.ram_mib == 256)
        .map(|(i, _)| i)
        .collect();
    let big: Vec<usize> = spec
        .nodes
        .iter()
        .enumerate()
        .filter(|(_, nd)| nd.ram_mib == 1024)
        .map(|(i, _)| i)
        .collect();
    let avg = |idx: &[usize]| idx.iter().map(|&i| r.d[i]).sum::<u64>() as f64 / idx.len() as f64;
    assert!(
        avg(&small) < avg(&big),
        "small-RAM nodes got {} rows on average vs {} for big-RAM",
        avg(&small),
        avg(&big)
    );
}

#[test]
fn epsilon_controls_accuracy() {
    let loose = dfpa_on("hcl15", 5120, 0.10);
    let tight = dfpa_on("hcl15", 5120, 0.025);
    assert!(loose.converged && tight.converged);
    assert!(loose.imbalance <= 0.10);
    assert!(tight.imbalance <= 0.025);
    // the paper's Table 3: tighter ε needs at least as many iterations
    assert!(tight.iterations >= loose.iterations);
}

#[test]
fn grid5000_converges_fast() {
    // paper Table 4: ≤ 3 iterations at ε=10%
    let r = dfpa_on("grid5000", 10240, 0.10);
    assert!(r.converged);
    assert!(r.iterations <= 4, "iterations {}", r.iterations);
}

fn tmp_store(tag: &str) -> (ModelStore, std::path::PathBuf) {
    let dir = hfpm::testkit::unique_temp_dir(&format!("test-dfpa-{tag}"));
    (ModelStore::open(&dir).unwrap(), dir)
}

/// The acceptance scenario: a cold DFPA run, its models round-tripped
/// through the on-disk store (save → load → merge), then a warm-started
/// run on the same simulated cluster converging in strictly fewer parallel
/// benchmark steps.
#[test]
fn warm_start_beats_cold_start_through_the_disk_store() {
    let spec = presets::hcl15();
    let n = 5120u64;
    let cfg = Matmul1dConfig::new(n, Strategy::Dfpa);
    let (store, dir) = tmp_store("warmcold");
    let keys: Vec<ModelKey> = spec.nodes.iter().map(|nd| cfg.store_key(&nd.host)).collect();

    // cold run
    let (mut cluster, _) = build_cluster(&spec, &cfg, Default::default()).unwrap();
    let mut bench = RowBench {
        cluster: &mut cluster,
        n,
    };
    let cold = run_dfpa(n, &mut bench, DfpaOptions::with_epsilon(0.025)).unwrap();
    assert!(cold.converged && !cold.warm_started);
    assert!(cold.iterations >= 2, "cold start cannot converge in one step");

    // round-trip: save → (re-open) load → merge a second observation set
    store
        .record_run(&keys, &cold.observations, &MergePolicy::default())
        .unwrap();
    drop(store);
    let store = ModelStore::open(&dir).unwrap();
    store
        .record_run(&keys, &cold.observations, &MergePolicy::default())
        .unwrap();
    let loaded = store.load(&keys[0]).unwrap().expect("persisted");
    assert_eq!(loaded.runs, 2, "merge across store generations");
    let warm_models = store.warm_models(&keys).unwrap().expect("stored");

    // warm run on a fresh cluster of the same spec
    let (mut cluster2, _) = build_cluster(&spec, &cfg, Default::default()).unwrap();
    let mut bench2 = RowBench {
        cluster: &mut cluster2,
        n,
    };
    let opts = DfpaOptions {
        epsilon: 0.025,
        warm_start: Some(WarmStart::new(warm_models)),
        ..Default::default()
    };
    let warm = run_dfpa(n, &mut bench2, opts).unwrap();
    assert!(warm.warm_started);
    assert!(warm.converged, "imbalance {}", warm.imbalance);
    assert_eq!(warm.d.iter().sum::<u64>(), n);
    assert!(
        warm.iterations < cold.iterations,
        "warm {} vs cold {} iterations",
        warm.iterations,
        cold.iterations
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Warm-start invariants under a hostile store: stale, mismatched points
/// (wrong sizes and wrong speed scale) must never break Σd = n or the
/// convergence flags.
#[test]
fn warm_start_invariants_hold_with_stale_store() {
    let spec = presets::hcl15();
    let n = 4096u64;
    let cfg = Matmul1dConfig::new(n, Strategy::Dfpa);
    let (store, dir) = tmp_store("stale");

    // fabricate a store measured on a "different" platform: tiny sizes,
    // inverted speed ordering, three orders of magnitude off
    for (rank, nd) in spec.nodes.iter().enumerate() {
        let mut sm = StoredModel::new(cfg.store_key(&nd.host));
        let mut fake = PiecewiseModel::new();
        fake.insert(2.0 + rank as f64, 1e3 * (rank + 1) as f64);
        fake.insert(40.0 + rank as f64, 5e2 * (rank + 1) as f64);
        sm.merge(&fake, &MergePolicy::default());
        store.save(&sm).unwrap();
    }
    let warm_models = store.warm_models(
        &spec
            .nodes
            .iter()
            .map(|nd| cfg.store_key(&nd.host))
            .collect::<Vec<_>>(),
    )
    .unwrap()
    .expect("fabricated store is non-empty");

    let (mut cluster, _) = build_cluster(&spec, &cfg, Default::default()).unwrap();
    let mut bench = RowBench {
        cluster: &mut cluster,
        n,
    };
    let opts = DfpaOptions {
        epsilon: 0.025,
        warm_start: Some(WarmStart::new(warm_models)),
        ..Default::default()
    };
    let r = run_dfpa(n, &mut bench, opts).unwrap();
    assert!(r.warm_started);
    assert_eq!(r.d.iter().sum::<u64>(), n, "Σd = n must hold");
    assert!(r.converged, "imbalance {}", r.imbalance);
    assert!(r.imbalance <= 0.025);
    // convergence flag consistency: every recorded iteration conserves n
    for rec in &r.records {
        assert_eq!(rec.d.iter().sum::<u64>(), n);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn dfpa_cost_minor_vs_app() {
    // the headline claim: DFPA's cost is a small fraction of the app
    let spec = presets::hcl15();
    let mut cfg = Matmul1dConfig::new(6144, Strategy::Dfpa);
    cfg.epsilon = 0.025;
    let r = hfpm::apps::matmul1d::run(&spec, &cfg).unwrap();
    let frac = r.partition_s / r.total_s;
    assert!(
        frac < 0.15,
        "DFPA cost fraction {frac:.3} exceeds the paper's ≤10% band"
    );
}

#[test]
fn partial_models_far_cheaper_than_full() {
    // Table 2's model-cost comparison: DFPA uses ≤ ~11 points; the full
    // model grid uses 160
    let r = dfpa_on("hcl15", 5120, 0.025);
    assert!(
        r.points_per_processor() <= 20,
        "DFPA used {} points",
        r.points_per_processor()
    );
    let spec = presets::hcl15();
    let nodes = hfpm::cluster::node::build_nodes(
        &spec,
        hfpm::fpm::analytic::Footprint::matmul_1d(5120),
        32,
    );
    let full = hfpm::baselines::ffmpa::full_grid_build_cost(&nodes, 8192);
    assert_eq!(full.points_per_proc, 160);
    assert!(
        full.parallel_s > 10.0 * r.total_virtual_s,
        "full build {} vs DFPA {}",
        full.parallel_s,
        r.total_virtual_s
    );
}

#[test]
fn dfpa_matches_ffmpa_distribution() {
    // "In all our experiments, the DFPA returned almost the same data
    // distribution as the FFMPA."
    let spec = presets::hcl15();
    let n = 4096u64;
    let r = dfpa_on("hcl15", n, 0.025);
    let nodes = hfpm::cluster::node::build_nodes(
        &spec,
        hfpm::fpm::analytic::Footprint::matmul_1d(n as usize),
        32,
    );
    let (models, _) = hfpm::baselines::ffmpa::build_full_models_for_n(&nodes, n, 0.0, 1);
    let d_ffmpa = hfpm::baselines::ffmpa::partition_rows(&models, n, n).unwrap();
    for (i, (a, b)) in r.d.iter().zip(&d_ffmpa).enumerate() {
        let diff = a.abs_diff(*b) as f64;
        let tol = (n as f64 / 15.0) * 0.25; // within 25% of a fair share
        assert!(diff <= tol, "node {i}: DFPA {a} vs FFMPA {b}");
    }
}
