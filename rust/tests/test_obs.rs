//! Integration tests for the obs layer's exporters, driven end-to-end
//! through a real observed workload: JSONL schema stability, Chrome
//! trace_event validity (and per-track `ts` monotonicity), cross-layer
//! coverage (session phases, per-rank engine tracks, store-service
//! commits), and the sink's no-silent-loss guarantee under saturation.

use hfpm::adapt::Strategy;
use hfpm::apps::jacobi;
use hfpm::cluster::presets;
use hfpm::modelstore::json::{self, Value};
use hfpm::modelstore::{StoreService, StoreServiceConfig};
use hfpm::obs::export::{to_chrome_trace, to_jsonl, PID_VIRT, PID_WALL};
use hfpm::obs::{ObsEvent, ObsSink, ObsSummary};
use hfpm::testkit::unique_temp_dir;

/// Run one small jacobi workload with the given sink, routing model saves
/// through a store service that shares it (so the trace has all three
/// layers: session, engine, store).
fn observed_jacobi(sink: &ObsSink) -> (Vec<ObsEvent>, ObsSummary) {
    let dir = unique_temp_dir("test-obs-jacobi");
    {
        let svc = StoreService::open_with(
            &dir,
            StoreServiceConfig {
                obs: sink.clone(),
                ..Default::default()
            },
        )
        .expect("open store service");
        let spec = presets::mini4();
        let mut cfg = jacobi::JacobiConfig::new(512, Strategy::Dfpa);
        cfg.sweeps = 6;
        cfg.rebalance_every = 2;
        cfg.store_service = Some(svc.clone());
        cfg.obs = sink.clone();
        jacobi::run(&spec, &cfg).expect("observed jacobi run");
        // svc (and cfg's clone) drop here: the writer joins, so every
        // commit span is in the queue before we drain
    }
    let _ = std::fs::remove_dir_all(&dir);
    let summary = sink.summary().expect("enabled sink");
    (sink.drain(), summary)
}

/// Keys of a JSON object, in serialized order.
fn keys(v: &Value) -> Vec<String> {
    match v {
        Value::Obj(pairs) => pairs.iter().map(|(k, _)| k.clone()).collect(),
        other => panic!("expected object, got {other:?}"),
    }
}

#[test]
fn jsonl_schema_is_stable_per_kind() {
    let sink = ObsSink::bounded(1 << 16);
    let (events, summary) = observed_jacobi(&sink);
    assert!(!events.is_empty(), "observed run must record events");
    let text = to_jsonl(&events, &summary);
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), events.len() + 1, "one line per event + meta");

    // golden key sets — the machine-readable contract of the JSONL format
    let span_keys = [
        "kind",
        "layer",
        "name",
        "id",
        "parent",
        "rank",
        "wall_begin_s",
        "wall_end_s",
        "virt_begin_s",
        "virt_end_s",
    ];
    let instant_keys = ["kind", "layer", "name", "rank", "wall_s", "virt_s", "detail"];
    let meta_keys = ["kind", "emitted", "recorded", "dropped", "counters", "hists"];

    for line in &lines {
        let v = json::parse(line).expect("every line is standalone JSON");
        let kind = v.get("kind").and_then(|k| k.as_str()).expect("kind field");
        let expect: &[&str] = match kind {
            "span" => &span_keys,
            "instant" => &instant_keys,
            "meta" => &meta_keys,
            other => panic!("unknown kind `{other}` in line: {line}"),
        };
        assert_eq!(keys(&v), expect, "schema drift in a `{kind}` line: {line}");
        let layers = ["session", "engine", "store", "sweep"];
        if kind != "meta" {
            let layer = v.get("layer").and_then(|l| l.as_str()).expect("layer");
            assert!(layers.contains(&layer), "unknown layer `{layer}`");
        }
    }
    // exactly one meta line, and it is the last one
    let meta = json::parse(lines.last().expect("meta")).expect("meta parses");
    assert_eq!(meta.get("kind").and_then(|k| k.as_str()), Some("meta"));
    assert_eq!(
        lines
            .iter()
            .filter(|l| l.contains("\"kind\":\"meta\""))
            .count(),
        1
    );
}

#[test]
fn chrome_trace_covers_all_layers_on_valid_tracks() {
    let sink = ObsSink::bounded(1 << 16);
    let (events, summary) = observed_jacobi(&sink);
    assert_eq!(summary.dropped, 0, "capacity must fit this run");
    let text = to_chrome_trace(&events, &summary);
    let doc = json::parse(&text).expect("Chrome trace is valid JSON");
    let tes = doc
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .expect("traceEvents array");

    let name_of = |e: &Value| e.get("name").and_then(|n| n.as_str()).map(String::from);
    let pid_of = |e: &Value| e.get("pid").and_then(|p| p.as_f64()).unwrap_or(-1.0) as u64;
    let cat_of = |e: &Value| e.get("cat").and_then(|c| c.as_str()).map(String::from);

    // session phases on both clock processes
    for phase in ["run", "partition", "execute", "store-flush"] {
        assert!(
            tes.iter()
                .any(|e| name_of(e).as_deref() == Some(phase) && pid_of(e) == PID_WALL),
            "missing session phase `{phase}` on the wall process"
        );
    }
    assert!(
        tes.iter()
            .any(|e| name_of(e).as_deref() == Some("partition") && pid_of(e) == PID_VIRT),
        "partition must also land on the virtual-clock process"
    );
    // ≥1 per-rank engine frame track (rank tids start at 10)
    assert!(
        tes.iter().any(|e| {
            cat_of(e).as_deref() == Some("engine")
                && name_of(e).as_deref() == Some("frame")
                && e.get("tid").and_then(|t| t.as_f64()).unwrap_or(0.0) >= 10.0
        }),
        "no per-rank engine frame events in the trace"
    );
    assert!(
        tes.iter().any(|e| {
            e.get("ph").and_then(|p| p.as_str()) == Some("M") && e.render().contains("rank 0")
        }),
        "rank 0 thread_name metadata missing"
    );
    // store-service commits (wall-only layer)
    assert!(
        tes.iter()
            .any(|e| cat_of(e).as_deref() == Some("store")
                && name_of(e).as_deref() == Some("commit")),
        "no store-service commit span in the trace"
    );
    // loss accounting is part of the document
    let other = doc.get("otherData").expect("otherData");
    assert_eq!(other.get("dropped").and_then(|d| d.as_f64()), Some(0.0));
}

#[test]
fn chrome_trace_ts_non_decreasing_within_every_track() {
    let sink = ObsSink::bounded(1 << 16);
    let (events, summary) = observed_jacobi(&sink);
    let doc = json::parse(&to_chrome_trace(&events, &summary)).expect("valid JSON");
    let tes = doc
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .expect("traceEvents");
    let mut last: std::collections::BTreeMap<(u64, u64), f64> = Default::default();
    let mut timed = 0usize;
    for e in tes {
        if e.get("ph").and_then(|p| p.as_str()) == Some("M") {
            continue;
        }
        let pid = e.get("pid").and_then(|p| p.as_f64()).expect("pid") as u64;
        let tid = e.get("tid").and_then(|t| t.as_f64()).expect("tid") as u64;
        let ts = e.get("ts").and_then(|t| t.as_f64()).expect("numeric ts");
        assert!(ts.is_finite(), "non-finite ts on track ({pid},{tid})");
        if let Some(prev) = last.get(&(pid, tid)) {
            assert!(
                ts >= *prev,
                "ts regressed on track ({pid},{tid}): {ts} < {prev}"
            );
        }
        last.insert((pid, tid), ts);
        timed += 1;
    }
    assert!(timed > 0, "trace must contain timed events");
    assert!(last.keys().len() >= 3, "expected several distinct tracks");
}

#[test]
fn saturated_sink_reports_drops_in_both_exports() {
    // a capacity this small cannot hold a jacobi run: drops are expected,
    // and they must be *counted*, never silent
    let sink = ObsSink::bounded(8);
    let (events, summary) = observed_jacobi(&sink);
    assert!(events.len() <= 8);
    assert!(summary.dropped > 0, "tiny sink must saturate");
    assert_eq!(summary.emitted, summary.recorded + summary.dropped);

    let text = to_jsonl(&events, &summary);
    let meta = json::parse(text.lines().last().expect("meta")).expect("meta parses");
    let dropped = meta.get("dropped").and_then(|d| d.as_f64()).expect("dropped");
    assert!(dropped > 0.0, "JSONL meta must surface the loss");

    let doc = json::parse(&to_chrome_trace(&events, &summary)).expect("valid JSON");
    let od = doc.get("otherData").expect("otherData");
    assert_eq!(
        od.get("dropped").and_then(|d| d.as_f64()),
        Some(summary.dropped as f64),
        "Chrome trace must surface the loss"
    );
}

#[test]
fn workload_report_carries_the_obs_summary() {
    let sink = ObsSink::bounded(1 << 16);
    let spec = presets::mini4();
    let mut cfg = jacobi::JacobiConfig::new(512, Strategy::Dfpa);
    cfg.sweeps = 4;
    cfg.obs = sink.clone();
    let r = jacobi::run(&spec, &cfg).expect("observed run");
    let obs = r.obs.expect("observed run must merge a summary");
    assert!(obs.emitted > 0);
    assert_eq!(obs.emitted, obs.recorded + obs.dropped);

    let unobserved = jacobi::run(&spec, &jacobi::JacobiConfig::new(512, Strategy::Dfpa))
        .expect("unobserved run");
    assert!(unobserved.obs.is_none(), "no sink → no summary");
}
