//! Integration tests for the concurrent model-store service: order
//! tolerance of concurrent merges, the zero-drop guarantee under high
//! contention, and the session-level warm-start path through snapshots.

use hfpm::fpm::PiecewiseModel;
use hfpm::modelstore::{
    Family, MergePolicy, ModelKey, ModelStore, ObsBatch, StoreService, StoreServiceConfig,
    StoredModel,
};
use hfpm::testkit::unique_temp_dir;
use std::sync::Barrier;

fn point(x: f64, s: f64) -> PiecewiseModel {
    let mut m = PiecewiseModel::new();
    m.insert(x, s);
    m
}

/// A merge policy whose result is independent of merge order: no per-run
/// decay (1.0 — older points keep full weight no matter how many merges
/// follow), no wall-clock decay, and room for every point.
fn commutative_policy() -> MergePolicy {
    MergePolicy {
        decay: 1.0,
        min_weight: 1e-6,
        max_points: 1024,
        blend_tol_rel: 1e-9,
        half_life_s: None,
    }
}

/// Disjoint keys: each session writes its own key, so the writer applies
/// every session's batches in that session's submit order (the channel is
/// FIFO). The per-key result must match a serial `merge_at` replay exactly
/// — same points, same speeds, same weights — even under the default
/// (order-sensitive) decaying policy.
#[test]
fn concurrent_disjoint_keys_match_serial_replay() {
    const SESSIONS: usize = 8;
    const RUNS: usize = 6;
    let dir = unique_temp_dir("svc-disjoint");
    let handle = StoreService::open(&dir).unwrap();

    let barrier = Barrier::new(SESSIONS);
    std::thread::scope(|scope| {
        for s in 0..SESSIONS {
            let handle = handle.clone();
            let barrier = &barrier;
            scope.spawn(move || {
                let key = ModelKey::new(&format!("h{s}"), "k", "sim");
                barrier.wait();
                for r in 0..RUNS {
                    let mut b = ObsBatch::at(1_000_000.0 + r as f64);
                    b.insert(
                        key.clone(),
                        Family::Speed,
                        point(100.0 + r as f64 * 50.0, 3.0 + s as f64),
                    );
                    handle.submit(b).unwrap();
                }
            });
        }
    });
    let stats = handle.flush().unwrap();
    assert_eq!(stats.merged_batches, (SESSIONS * RUNS) as u64);
    assert_eq!(stats.dropped_saves, 0);
    drop(handle);

    // serial replay with the same policy and timestamps
    let store = ModelStore::open(&dir).unwrap();
    for s in 0..SESSIONS {
        let key = ModelKey::new(&format!("h{s}"), "k", "sim");
        let mut expect = StoredModel::new(key.clone());
        for r in 0..RUNS {
            expect.merge_at(
                &point(100.0 + r as f64 * 50.0, 3.0 + s as f64),
                &MergePolicy::default(),
                1_000_000.0 + r as f64,
            );
        }
        let got = store.load(&key).unwrap().expect("session key persisted");
        assert_eq!(got.points.len(), expect.points.len(), "key h{s}");
        for (g, e) in got.points.iter().zip(&expect.points) {
            assert_eq!(g.x, e.x, "key h{s}");
            assert_eq!(g.s, e.s, "key h{s}");
            assert_eq!(g.w, e.w, "key h{s}");
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Overlapping key: every session merges into the *same* model, so the
/// interleaving is nondeterministic. Under a commutative policy (no decay,
/// distinct sizes, one shared timestamp) the merged point set must equal a
/// serial replay in any order — concurrency changes nothing but the order.
#[test]
fn concurrent_overlapping_key_is_order_tolerant() {
    const SESSIONS: usize = 8;
    const RUNS: usize = 5;
    let dir = unique_temp_dir("svc-overlap");
    let handle = StoreService::open_with(
        &dir,
        StoreServiceConfig {
            merge_policy: commutative_policy(),
            ..Default::default()
        },
    )
    .unwrap();
    let key = ModelKey::new("shared", "k", "sim");

    let barrier = Barrier::new(SESSIONS);
    std::thread::scope(|scope| {
        for s in 0..SESSIONS {
            let handle = handle.clone();
            let key = key.clone();
            let barrier = &barrier;
            scope.spawn(move || {
                barrier.wait();
                for r in 0..RUNS {
                    let i = s * RUNS + r;
                    let mut b = ObsBatch::at(1_000_000.0);
                    b.insert(
                        key.clone(),
                        Family::Speed,
                        point(100.0 + i as f64 * 10.0, 1.0 + i as f64),
                    );
                    handle.submit(b).unwrap();
                }
            });
        }
    });
    let stats = handle.flush().unwrap();
    assert_eq!(stats.merged_batches, (SESSIONS * RUNS) as u64);
    assert_eq!(stats.dropped_saves, 0);
    drop(handle);

    // serial replay in reverse submission order: same set must come out
    let mut expect = StoredModel::new(key.clone());
    for i in (0..SESSIONS * RUNS).rev() {
        expect.merge_at(
            &point(100.0 + i as f64 * 10.0, 1.0 + i as f64),
            &commutative_policy(),
            1_000_000.0,
        );
    }
    let got = ModelStore::open(&dir)
        .unwrap()
        .load(&key)
        .unwrap()
        .expect("shared key persisted");
    assert_eq!(got.points.len(), SESSIONS * RUNS);
    assert_eq!(got.points.len(), expect.points.len());
    // both are sorted by x, so positional comparison is set comparison
    for (g, e) in got.points.iter().zip(&expect.points) {
        assert_eq!(g.x, e.x);
        assert_eq!(g.s, e.s);
        assert_eq!(g.w, e.w);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// High contention: many sessions hammering one service. Nothing may be
/// dropped, every batch must merge, and every key must reach disk.
#[test]
fn high_contention_drops_nothing() {
    const SESSIONS: usize = 32;
    const RUNS: usize = 8;
    let dir = unique_temp_dir("svc-contention");
    let handle = StoreService::open(&dir).unwrap();

    let barrier = Barrier::new(SESSIONS);
    std::thread::scope(|scope| {
        for s in 0..SESSIONS {
            let handle = handle.clone();
            let barrier = &barrier;
            scope.spawn(move || {
                let key = ModelKey::new(&format!("n{s:02}"), "k", "sim");
                barrier.wait();
                for r in 0..RUNS {
                    let mut b = ObsBatch::new();
                    b.insert(
                        key.clone(),
                        Family::Speed,
                        point(64.0 + r as f64 * 64.0, 2.0),
                    );
                    handle.submit(b).unwrap();
                }
            });
        }
    });
    let stats = handle.flush().unwrap();
    assert_eq!(stats.dropped_saves, 0, "zero-drop guarantee: {stats:?}");
    assert_eq!(stats.merged_batches, (SESSIONS * RUNS) as u64);
    assert_eq!(stats.corrupt_files, 0);
    drop(handle);

    let store = ModelStore::open(&dir).unwrap();
    assert_eq!(store.entries().unwrap().len(), SESSIONS);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The session-level path: two app runs sharing one service handle. The
/// first cold-starts and submits its observations; after a flush the
/// second warm-starts from the published snapshot — without ever touching
/// the store directory from the app thread.
#[test]
fn app_runs_warm_start_through_the_service() {
    use hfpm::apps::matmul1d::{self, Matmul1dConfig, Strategy};
    use hfpm::cluster::presets;

    let dir = unique_temp_dir("svc-warmstart");
    let handle = StoreService::open(&dir).unwrap();
    let spec = presets::mini4();
    let mut cfg = Matmul1dConfig::new(2048, Strategy::Dfpa);
    cfg.store_service = Some(handle.clone());

    let first = matmul1d::run(&spec, &cfg).unwrap();
    assert!(!first.warm_started, "empty service must cold-start");
    // submission is asynchronous: flush before the next run reads
    let stats = handle.flush().unwrap();
    assert_eq!(stats.dropped_saves, 0);
    assert!(stats.merged_batches >= 1);

    let second = matmul1d::run(&spec, &cfg).unwrap();
    assert!(second.warm_started, "snapshot must seed the second run");
    assert!(
        second.iterations <= first.iterations,
        "warm {} vs cold {}",
        second.iterations,
        first.iterations
    );
    let run_stats = second.store_stats.expect("service runs report stats");
    assert_eq!(run_stats.dropped_saves, 0);

    // the service owned all persistence: the directory holds one model
    // per host, written by the writer thread alone
    drop(handle);
    let store = ModelStore::open(&dir).unwrap();
    assert_eq!(store.entries().unwrap().len(), spec.size());
    let _ = std::fs::remove_dir_all(&dir);
}
