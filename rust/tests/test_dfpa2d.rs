//! Integration tests: nested 2D DFPA on the cluster runtime.

use hfpm::apps::matmul2d::{grid_shape, run, Matmul2dConfig};
use hfpm::apps::Strategy;
use hfpm::cluster::presets;
use hfpm::dfpa2d::nested::Dfpa2dOptions;

#[test]
fn hcl_16node_4x4_converges() {
    let spec = presets::hcl();
    let mut cfg = Matmul2dConfig::new(8192, Strategy::Dfpa);
    cfg.epsilon = 0.1;
    let r = run(&spec, &cfg).unwrap();
    assert_eq!((r.p, r.q), (4, 4));
    assert!(r.imbalance < 0.35, "imbalance {}", r.imbalance);
    assert!(r.iterations > 0);
}

#[test]
fn table5_shape_overhead_grows_with_n() {
    // Table 5: the DFPA cost % grows once paging territory is reached
    let spec = presets::hcl();
    let small = run(&spec, &Matmul2dConfig::new(8192, Strategy::Dfpa)).unwrap();
    let large = run(&spec, &Matmul2dConfig::new(16384, Strategy::Dfpa)).unwrap();
    assert!(
        large.iterations >= small.iterations,
        "iterations: {} vs {}",
        large.iterations,
        small.iterations
    );
    // both stay under the paper's worst observed 17%... with margin
    assert!(small.overhead_pct < 25.0);
    assert!(large.overhead_pct < 35.0);
}

#[test]
fn widths_track_column_strength() {
    // put all the fast nodes in one column: that column must end wider
    let spec = presets::mini4(); // p1 fast, p2 slower, p3 small-RAM, p4 slow
    let mut cfg = Matmul2dConfig::new(4096, Strategy::Dfpa);
    cfg.epsilon = 0.1;
    let r = run(&spec, &cfg).unwrap();
    // grid 2×2: column 0 = {p1, p2}, column 1 = {p3, p4} (column-major)
    assert_eq!((r.p, r.q), (2, 2));
    assert!(
        r.widths[0] > r.widths[1],
        "strong column not wider: {:?}",
        r.widths
    );
}

#[test]
fn optimization_flags_affect_iterations() {
    // disabling warm starts/freezing must not break convergence (sanity on
    // the ablation knobs used by bench_micro)
    let spec = presets::mini4();
    let nodes = hfpm::cluster::node::build_nodes(
        &spec,
        hfpm::fpm::analytic::Footprint::matmul_2d(32, 64),
        32,
    );
    let execs: Vec<Box<dyn hfpm::cluster::executor::NodeExecutor>> = nodes
        .into_iter()
        .map(|n| Box::new(n) as Box<dyn hfpm::cluster::executor::NodeExecutor>)
        .collect();
    let cluster = hfpm::cluster::virtual_cluster::VirtualCluster::spawn(
        execs,
        hfpm::cluster::comm::CommModel::new(spec),
        Default::default(),
    );
    let mut grid = hfpm::cluster::virtual_cluster::VirtualCluster2d::new(cluster, 2, 2).unwrap();
    let opts = Dfpa2dOptions {
        epsilon: 0.15,
        epsilon_inner: 0.15,
        width_freeze_rel: 0.0,  // freezing off
        time_cap_mult: None,    // capping off
        ..Default::default()
    };
    let r = hfpm::dfpa2d::run_dfpa2d(128, 128, &mut grid, opts).unwrap();
    assert_eq!(r.widths.iter().sum::<u64>(), 128);
    assert!(r.inner_iterations > 0);
}

#[test]
fn grid_shape_covers_paper_sizes() {
    assert_eq!(grid_shape(16), (4, 4)); // HCL
    assert_eq!(grid_shape(28), (7, 4)); // Grid5000
}
