//! Failure-injection integration tests: dead workers and stragglers.

use hfpm::apps::matmul1d::{run_with_faults, Matmul1dConfig, Strategy};
use hfpm::cluster::faults::FaultPlan;
use hfpm::cluster::presets;
use hfpm::error::HfpmError;

#[test]
fn dead_worker_fails_the_run_cleanly() {
    let spec = presets::mini4();
    let cfg = Matmul1dConfig::new(2048, Strategy::Dfpa);
    let faults = FaultPlan::none().with_death(1, 1);
    let err = run_with_faults(&spec, &cfg, faults).unwrap_err();
    match err {
        HfpmError::WorkerFailed { rank, .. } => assert_eq!(rank, 1),
        other => panic!("expected WorkerFailed, got {other}"),
    }
}

#[test]
fn death_at_step_zero_fails_immediately() {
    let spec = presets::mini4();
    let cfg = Matmul1dConfig::new(2048, Strategy::Even);
    // Even runs exactly one superstep (the final matmul benchmark)
    let faults = FaultPlan::none().with_death(3, 0);
    assert!(run_with_faults(&spec, &cfg, faults).is_err());
}

#[test]
fn straggler_is_absorbed_by_dfpa() {
    // a 3× straggler is not a failure — DFPA simply gives it less work
    let spec = presets::mini4();
    let mut cfg = Matmul1dConfig::new(4096, Strategy::Dfpa);
    cfg.epsilon = 0.05;
    let healthy = run_with_faults(&spec, &cfg, FaultPlan::none()).unwrap();
    let faults = FaultPlan::none().with_straggler(0, 3.0, 0);
    let strag = run_with_faults(&spec, &cfg, faults).unwrap();
    assert!(
        strag.d[0] < healthy.d[0],
        "straggler rows {} !< healthy rows {}",
        strag.d[0],
        healthy.d[0]
    );
    // and the app still balances
    assert!(strag.imbalance < 0.10, "imbalance {}", strag.imbalance);
}

#[test]
fn late_straggler_does_not_break_convergence() {
    // the platform changes mid-run (a node slows down after step 2): DFPA
    // re-measures every iteration, so it adapts or at worst uses more
    // iterations — it must not error out
    let spec = presets::mini4();
    let mut cfg = Matmul1dConfig::new(4096, Strategy::Dfpa);
    cfg.epsilon = 0.10;
    let faults = FaultPlan::none().with_straggler(2, 2.0, 2);
    let r = run_with_faults(&spec, &cfg, faults).unwrap();
    assert_eq!(r.d.iter().sum::<u64>(), 4096);
}

#[test]
fn even_strategy_ignores_stragglers() {
    // Even doesn't adapt: a straggler slows the app but the distribution
    // stays uniform — the contrast DFPA exists to fix
    let spec = presets::mini4();
    let cfg = Matmul1dConfig::new(2048, Strategy::Even);
    let healthy = run_with_faults(&spec, &cfg, FaultPlan::none()).unwrap();
    let faults = FaultPlan::none().with_straggler(1, 4.0, 0);
    let strag = run_with_faults(&spec, &cfg, faults).unwrap();
    assert_eq!(healthy.d, strag.d);
    assert!(strag.compute_s > 2.0 * healthy.compute_s);
}
