//! The adapt layer: registry/`Distributor` parity with the legacy entry
//! points, and `AdaptiveSession` store round-trips.

use hfpm::adapt::{
    registry, AdaptiveSession, Distribution, Dfpa, Distributor, Distributor2d, Observations,
    Outcome, SessionCtx, Strategy,
};
use hfpm::baselines::{cpm_app, factoring};
use hfpm::dfpa::{run_dfpa, Benchmarker, DfpaOptions, StepReport, WarmStart};
use hfpm::dfpa2d::Benchmarker2d;
use hfpm::fpm::{ConstantModel, PiecewiseModel, ScaledModel, SpeedFunction};
use hfpm::modelstore::{ModelKey, ModelStore};
use hfpm::testkit::unique_temp_dir;
use hfpm::Result;

/// Deterministic benchmarker over constant ground-truth speeds — the
/// `ModelBench` fixture of the dfpa unit tests, reachable from an
/// integration test.
struct ModelBench {
    speeds: Vec<f64>,
    steps: usize,
}

impl ModelBench {
    fn new(speeds: &[f64]) -> Self {
        Self {
            speeds: speeds.to_vec(),
            steps: 0,
        }
    }
}

impl Benchmarker for ModelBench {
    fn processors(&self) -> usize {
        self.speeds.len()
    }

    fn run_parallel(&mut self, d: &[u64]) -> Result<StepReport> {
        self.steps += 1;
        let times: Vec<f64> = d
            .iter()
            .zip(&self.speeds)
            .map(|(&di, &s)| {
                if di == 0 {
                    0.0
                } else {
                    ConstantModel(s).time(di as f64)
                }
            })
            .collect();
        let max = times.iter().cloned().fold(0.0f64, f64::max);
        Ok(StepReport {
            times,
            virtual_cost_s: max,
        })
    }
}

const SPEEDS: [f64; 3] = [10.0, 30.0, 20.0];

fn make_1d(strategy: Strategy) -> Box<dyn Distributor> {
    // none of the parity strategies need app resources
    strategy
        .make_1d(&registry::AppResources {
            nodes: &[],
            n: 0,
            unit_scale: 1.0,
            noise_rel: 0.0,
            seed: 0,
        })
        .unwrap()
}

fn distribute(strategy: Strategy, n: u64, eps: f64) -> Vec<u64> {
    let mut bench = ModelBench::new(&SPEEDS);
    let out = make_1d(strategy)
        .distribute(n, &mut bench, &SessionCtx::with_epsilon(eps))
        .unwrap();
    out.distribution.into_1d().unwrap()
}

#[test]
fn even_registry_matches_legacy() {
    assert_eq!(
        distribute(Strategy::Even, 100, 0.05),
        hfpm::baselines::even::even_distribution(100, SPEEDS.len())
    );
}

#[test]
fn cpm_registry_matches_legacy() {
    let mut legacy_bench = ModelBench::new(&SPEEDS);
    let legacy = cpm_app::partition_cpm(600, &mut legacy_bench).unwrap();
    assert_eq!(distribute(Strategy::Cpm, 600, 0.05), legacy.d);
}

#[test]
fn dfpa_registry_matches_legacy() {
    let mut legacy_bench = ModelBench::new(&SPEEDS);
    let legacy = run_dfpa(600, &mut legacy_bench, DfpaOptions::with_epsilon(0.02)).unwrap();
    assert_eq!(distribute(Strategy::Dfpa, 600, 0.02), legacy.d);
}

#[test]
fn factoring_registry_matches_legacy() {
    let mut legacy_bench = ModelBench::new(&SPEEDS);
    let legacy = factoring::run_factoring(
        1000,
        &mut legacy_bench,
        0.5,
        factoring::Weighting::Adaptive,
    )
    .unwrap();
    assert_eq!(distribute(Strategy::Factoring, 1000, 0.05), legacy.executed);
}

#[test]
fn ffmpa_registry_matches_legacy() {
    // pre-built constant models; the registry factory path needs nodes, so
    // drive the Ffmpa distributor directly with the same models
    let models: Vec<PiecewiseModel> = SPEEDS
        .iter()
        .map(|&s| PiecewiseModel::constant(100.0, s))
        .collect();
    let views: Vec<ScaledModel<&PiecewiseModel>> =
        models.iter().map(|m| ScaledModel::new(m, 1.0)).collect();
    let legacy = hfpm::partition::partition(600, &views).unwrap().d;

    let mut dist = hfpm::adapt::Ffmpa {
        models,
        unit_scale: 1.0,
        model_build_s: Some(1.0),
    };
    let mut bench = ModelBench::new(&SPEEDS);
    let out = dist
        .distribute(600, &mut bench, &SessionCtx::default())
        .unwrap();
    assert_eq!(out.distribution.into_1d().unwrap(), legacy);
    assert_eq!(out.model_build_s, Some(1.0));
    assert_eq!(bench.steps, 0, "ffmpa must not benchmark");
}

#[test]
fn dfpa_warm_start_flows_through_session_ctx() {
    let mut cold_bench = ModelBench::new(&SPEEDS);
    let cold = run_dfpa(6000, &mut cold_bench, DfpaOptions::with_epsilon(0.01)).unwrap();

    let ctx = SessionCtx {
        epsilon: 0.01,
        warm_start: Some(WarmStart::new(cold.observations.clone())),
        ..Default::default()
    };
    let mut bench = ModelBench::new(&SPEEDS);
    let warm = Dfpa::default().distribute(6000, &mut bench, &ctx).unwrap();
    assert!(warm.warm_started);
    assert!(warm.benchmark_steps <= cold.iterations);
}

#[test]
fn session_flushes_observations_and_warm_starts() {
    let dir = unique_temp_dir("adapt-session");
    let keys: Vec<ModelKey> = (0..SPEEDS.len())
        .map(|i| ModelKey::new(&format!("node{i}"), "adapt_test", "sim"))
        .collect();
    let session = AdaptiveSession::new()
        .epsilon(0.01)
        .model_store(Some(dir.clone()));

    let mut dist = Dfpa::default();
    let cold = {
        let mut bench = ModelBench::new(&SPEEDS);
        session.run_1d(&mut dist, 6000, &mut bench, &keys).unwrap()
    };
    assert!(!cold.warm_started, "empty store must cold-start");

    // the flush must have written one model per measured processor
    let store = ModelStore::open(&dir).unwrap();
    assert_eq!(store.entries().unwrap().len(), SPEEDS.len());
    drop(store); // release the advisory lock before the next session run

    let warm = {
        let mut bench = ModelBench::new(&SPEEDS);
        session.run_1d(&mut dist, 6000, &mut bench, &keys).unwrap()
    };
    assert!(warm.warm_started, "populated store must warm-start");
    assert!(warm.benchmark_steps <= cold.benchmark_steps);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn non_store_strategies_leave_the_store_untouched() {
    // even/cpm/ffmpa/factoring neither warm-start nor observe: the session
    // must not open (or even create) the store, nor take its writer lock
    let dir = unique_temp_dir("adapt-nostore");
    let keys: Vec<ModelKey> = (0..SPEEDS.len())
        .map(|i| ModelKey::new(&format!("node{i}"), "adapt_test", "sim"))
        .collect();
    let session = AdaptiveSession::new().model_store(Some(dir.clone()));
    for strategy in [Strategy::Even, Strategy::Cpm, Strategy::Factoring] {
        let mut bench = ModelBench::new(&SPEEDS);
        let mut dist = make_1d(strategy);
        session
            .run_1d(dist.as_mut(), 600, &mut bench, &keys)
            .unwrap();
    }
    assert!(!dir.exists(), "non-store strategies created the store dir");
}

#[test]
fn factoring_outcome_is_flagged_as_executing_the_workload() {
    let mut bench = ModelBench::new(&SPEEDS);
    let out = make_1d(Strategy::Factoring)
        .distribute(1000, &mut bench, &SessionCtx::default())
        .unwrap();
    assert!(out.executes_workload);
    let mut bench = ModelBench::new(&SPEEDS);
    let out = make_1d(Strategy::Dfpa)
        .distribute(1000, &mut bench, &SessionCtx::with_epsilon(0.05))
        .unwrap();
    assert!(!out.executes_workload);
}

#[test]
fn session_trace_sink_writes_csv() {
    let dir = unique_temp_dir("adapt-trace");
    let path = dir.join("trace.csv");
    let session = AdaptiveSession::new().epsilon(0.02).trace_to(path.clone());
    let mut dist = Dfpa::default();
    let mut bench = ModelBench::new(&SPEEDS);
    let out = session.run_1d(&mut dist, 600, &mut bench, &[]).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.starts_with("iter,proc,d,time_s,speed,imbalance"));
    // one row per (iteration, processor) plus the header
    assert_eq!(
        text.lines().count(),
        1 + out.benchmark_steps * SPEEDS.len()
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn outcome_reports_observations_for_dfpa_only() {
    for (strategy, expect_obs) in [
        (Strategy::Even, false),
        (Strategy::Cpm, false),
        (Strategy::Dfpa, true),
        (Strategy::Factoring, false),
    ] {
        let mut bench = ModelBench::new(&SPEEDS);
        let out = make_1d(strategy)
            .distribute(600, &mut bench, &SessionCtx::with_epsilon(0.05))
            .unwrap();
        assert_eq!(
            !matches!(out.observations, Observations::None),
            expect_obs,
            "strategy {strategy:?}"
        );
        assert_eq!(out.strategy, strategy.name());
    }
}

/// Column-structured benchmarker over constant per-cell speeds, `[j][i]`.
struct GridBench {
    speeds: Vec<Vec<f64>>,
}

impl Benchmarker2d for GridBench {
    fn grid(&self) -> (usize, usize) {
        (self.speeds[0].len(), self.speeds.len())
    }

    fn run_column(
        &mut self,
        j: usize,
        width: u64,
        heights: &[u64],
        _cap: Option<f64>,
    ) -> Result<StepReport> {
        let times: Vec<f64> = heights
            .iter()
            .zip(&self.speeds[j])
            .map(|(&h, &s)| {
                if h == 0 {
                    0.0
                } else {
                    (h * width) as f64 / s
                }
            })
            .collect();
        let max = times.iter().cloned().fold(0.0f64, f64::max);
        Ok(StepReport {
            times,
            virtual_cost_s: max,
        })
    }
}

#[test]
fn dfpa2d_distributor_balances_the_grid() {
    let mut bench = GridBench {
        speeds: vec![vec![10.0, 20.0], vec![30.0, 40.0]],
    };
    let mut dist = hfpm::adapt::Dfpa2d;
    let out = dist
        .distribute(64, 64, &mut bench, &SessionCtx::with_epsilon(0.1))
        .unwrap();
    match out.distribution {
        Distribution::TwoD { widths, heights } => {
            assert_eq!(widths.iter().sum::<u64>(), 64);
            for hs in &heights {
                assert_eq!(hs.iter().sum::<u64>(), 64);
            }
        }
        other => panic!("expected a 2D distribution, got {other:?}"),
    }
    assert!(matches!(out.observations, Observations::TwoD(_)));
}

/// A store-using 2D distributor that reports an observation grid of the
/// wrong shape — the fixture for the session's shape guard.
struct MisshapenObserver {
    obs_cols: usize,
    obs_rows: usize,
}

impl Distributor2d for MisshapenObserver {
    fn name(&self) -> &'static str {
        "misshapen"
    }

    fn uses_model_store(&self) -> bool {
        true
    }

    fn distribute(
        &mut self,
        m: u64,
        n: u64,
        bench: &mut dyn Benchmarker2d,
        _ctx: &SessionCtx,
    ) -> Result<Outcome> {
        let (p, q) = bench.grid();
        let mut out = Outcome::immediate(
            self.name(),
            Distribution::TwoD {
                widths: hfpm::baselines::even::even_distribution(n, q),
                heights: vec![hfpm::baselines::even::even_distribution(m, p); q],
            },
        );
        out.observations = Observations::TwoD(vec![
            vec![PiecewiseModel::constant(8.0, 5.0); self.obs_rows];
            self.obs_cols
        ]);
        Ok(out)
    }
}

#[test]
fn run_2d_rejects_observation_grids_that_mismatch_the_keys() {
    // regression: the session used to zip-truncate silently, dropping
    // whole columns of measurements when the shapes disagreed
    let dir = unique_temp_dir("adapt-2d-mismatch");
    let session = AdaptiveSession::new().model_store(Some(dir.clone()));
    let keys: Vec<Vec<ModelKey>> = (0..2)
        .map(|j| {
            (0..2)
                .map(|i| ModelKey::new(&format!("n{j}{i}"), "k", "sim"))
                .collect()
        })
        .collect();
    let mut bench = GridBench {
        speeds: vec![vec![10.0, 20.0], vec![30.0, 40.0]],
    };
    // wrong column count and wrong row count both error
    for (cols, rows) in [(1usize, 2usize), (2, 3)] {
        let mut dist = MisshapenObserver {
            obs_cols: cols,
            obs_rows: rows,
        };
        let err = session
            .run_2d(&mut dist, 8, 8, &mut bench, &keys)
            .unwrap_err();
        assert!(
            err.to_string().contains("do not match the model-key grid"),
            "({cols}×{rows}): {err}"
        );
    }
    // the matching shape still records fine
    let mut dist = MisshapenObserver {
        obs_cols: 2,
        obs_rows: 2,
    };
    session.run_2d(&mut dist, 8, 8, &mut bench, &keys).unwrap();
    let store = ModelStore::open(&dir).unwrap();
    assert_eq!(store.entries().unwrap().len(), 4);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn cold_store_rejects_misaligned_carry() {
    // regression: the carry-length check only fired when the store returned
    // models, so with a *cold* store a wrong-length carry was wrapped
    // positionally misaligned and surfaced only later — as a confusing
    // record_run "2 keys vs 3 models" at flush time — or not at all
    let dir = unique_temp_dir("adapt-carry-mismatch");
    let session = AdaptiveSession::new().model_store(Some(dir.clone()));
    let keys: Vec<ModelKey> = (0..2)
        .map(|i| ModelKey::new(&format!("node{i}"), "k", "sim"))
        .collect();
    let carry = vec![PiecewiseModel::constant(10.0, 5.0); 3];
    let mut dist = Dfpa::default();
    let mut bench = ModelBench::new(&SPEEDS);
    let err = session
        .run_1d_seeded(&mut dist, 600, &mut bench, &keys, Some(&carry), None)
        .unwrap_err();
    assert!(
        err.to_string().contains("carry seeds 3 models for 2 store keys"),
        "got: {err}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn empty_keys_skip_1d_persistence_instead_of_erroring() {
    // regression: run_1d with a store but no keys let record_run fail with
    // "0 keys vs N models"; the documented contract is skip-with-warning
    let dir = unique_temp_dir("adapt-nokeys-1d");
    let session = AdaptiveSession::new()
        .epsilon(0.02)
        .model_store(Some(dir.clone()));
    let mut dist = Dfpa::default();
    let mut bench = ModelBench::new(&SPEEDS);
    let out = session.run_1d(&mut dist, 600, &mut bench, &[]).unwrap();
    assert!(out.benchmark_steps >= 1);
    let store = ModelStore::open(&dir).unwrap();
    assert!(store.entries().unwrap().is_empty(), "nothing may persist");
    drop(store);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn empty_key_grid_skips_2d_persistence() {
    // the 2D side of the same contract: observations are dropped with a
    // warning instead of vanishing in a zip over zero key columns
    let dir = unique_temp_dir("adapt-nokeys-2d");
    let session = AdaptiveSession::new().model_store(Some(dir.clone()));
    let mut bench = GridBench {
        speeds: vec![vec![10.0, 20.0], vec![30.0, 40.0]],
    };
    let mut dist = hfpm::adapt::Dfpa2d;
    let out = session.run_2d(&mut dist, 8, 8, &mut bench, &[]).unwrap();
    assert!(matches!(out.observations, Observations::TwoD(_)));
    let store = ModelStore::open(&dir).unwrap();
    assert!(store.entries().unwrap().is_empty(), "nothing may persist");
    drop(store);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn seeded_run_warm_starts_without_a_store() {
    // the within-run carry path iterative workloads use: models learned in
    // an earlier phase seed the next repartition directly
    let mut cold_bench = ModelBench::new(&SPEEDS);
    let session = AdaptiveSession::new().epsilon(0.01);
    let mut dist = Dfpa::default();
    let cold = session
        .run_1d(&mut dist, 6000, &mut cold_bench, &[])
        .unwrap();
    assert!(!cold.warm_started);
    let carry = match &cold.observations {
        Observations::OneD(obs) => obs.clone(),
        other => panic!("expected 1D observations, got {other:?}"),
    };
    let mut bench = ModelBench::new(&SPEEDS);
    let warm = session
        .run_1d_seeded(&mut dist, 6000, &mut bench, &[], Some(&carry[..]), None)
        .unwrap();
    assert!(warm.warm_started, "carry models must warm-start");
    assert!(warm.benchmark_steps <= cold.benchmark_steps);
}

#[test]
fn even2d_distributor_matches_even_splits() {
    let mut bench = GridBench {
        speeds: vec![vec![10.0, 20.0], vec![30.0, 40.0]],
    };
    let mut dist = hfpm::adapt::Even2d;
    let out = dist
        .distribute(10, 7, &mut bench, &SessionCtx::default())
        .unwrap();
    let (widths, heights) = out.distribution.into_2d().unwrap();
    assert_eq!(widths, hfpm::baselines::even::even_distribution(7, 2));
    for hs in heights {
        assert_eq!(hs, hfpm::baselines::even::even_distribution(10, 2));
    }
}
