//! End-to-end application tests: the 1D/2D matmul, Jacobi and LU apps
//! across strategies, plus the real-PJRT verified path when artifacts are
//! present.

use hfpm::apps::jacobi::{self, JacobiConfig};
use hfpm::apps::lu::{self, LuConfig};
use hfpm::apps::matmul1d::{self, Matmul1dConfig};
use hfpm::apps::matmul2d::{self, Matmul2dConfig};
use hfpm::apps::Strategy;
use hfpm::cluster::presets;
use hfpm::testkit::unique_temp_dir;

#[test]
fn table2_shape_dfpa_within_10pct_of_ffmpa() {
    // Table 2: DFPA-based/FFMPA-based total ∈ [1.01, 1.10]
    let spec = presets::hcl15();
    for n in [3072u64, 4096] {
        let mut c_f = Matmul1dConfig::new(n, Strategy::Ffmpa);
        c_f.epsilon = 0.025;
        let mut c_d = Matmul1dConfig::new(n, Strategy::Dfpa);
        c_d.epsilon = 0.025;
        let rf = matmul1d::run(&spec, &c_f).unwrap();
        let rd = matmul1d::run(&spec, &c_d).unwrap();
        let ratio = rd.total_s / rf.total_s;
        assert!(
            (0.98..=1.25).contains(&ratio),
            "n={n}: DFPA/FFMPA ratio {ratio:.3}"
        );
    }
}

#[test]
fn app_times_grow_with_n() {
    let spec = presets::hcl15();
    let mut last = 0.0;
    for n in [2048u64, 4096, 6144] {
        let mut cfg = Matmul1dConfig::new(n, Strategy::Dfpa);
        cfg.epsilon = 0.1;
        let r = matmul1d::run(&spec, &cfg).unwrap();
        assert!(r.compute_s > last, "n={n}: {} !> {last}", r.compute_s);
        last = r.compute_s;
    }
}

#[test]
fn dfpa_app_beats_even_on_heterogeneous_cluster() {
    let spec = presets::hcl15();
    let mut c_even = Matmul1dConfig::new(4096, Strategy::Even);
    c_even.epsilon = 0.1;
    let mut c_dfpa = Matmul1dConfig::new(4096, Strategy::Dfpa);
    c_dfpa.epsilon = 0.1;
    let re = matmul1d::run(&spec, &c_even).unwrap();
    let rd = matmul1d::run(&spec, &c_dfpa).unwrap();
    assert!(
        rd.compute_s < 0.95 * re.compute_s,
        "dfpa {} vs even {}",
        rd.compute_s,
        re.compute_s
    );
}

#[test]
fn matmul2d_fig10_ordering() {
    // Fig 10: FFMPA ≤ DFPA < CPM on matmul time. The gap opens at sizes
    // where part of the grid pages (constant models mispredict there);
    // n=14336 puts the 256/512 MiB nodes past their RAM.
    let spec = presets::hcl();
    let n = 14336u64;
    let run_s = |s: Strategy| {
        let mut cfg = Matmul2dConfig::new(n, s);
        cfg.epsilon = 0.1;
        matmul2d::run(&spec, &cfg).unwrap()
    };
    let ffmpa = run_s(Strategy::Ffmpa);
    let dfpa = run_s(Strategy::Dfpa);
    let cpm = run_s(Strategy::Cpm);
    assert!(
        ffmpa.matmul_s <= dfpa.matmul_s * 1.10,
        "ffmpa {} vs dfpa {}",
        ffmpa.matmul_s,
        dfpa.matmul_s
    );
    assert!(
        dfpa.matmul_s < cpm.matmul_s,
        "dfpa {} vs cpm {} — the paper's 25% gap should favor dfpa",
        dfpa.matmul_s,
        cpm.matmul_s
    );
}

#[test]
fn matmul2d_partitions_are_complete() {
    let spec = presets::hcl();
    let cfg = Matmul2dConfig::new(8192, Strategy::Dfpa);
    let r = matmul2d::run(&spec, &cfg).unwrap();
    let m = cfg.m_blocks();
    assert_eq!(r.widths.iter().sum::<u64>(), m);
    for (j, hs) in r.heights.iter().enumerate() {
        assert_eq!(hs.iter().sum::<u64>(), m, "column {j}");
    }
    // total block area preserved
    let area: u64 = (0..r.q)
        .map(|j| r.widths[j] * r.heights[j].iter().sum::<u64>())
        .sum();
    assert_eq!(area, m * m);
}

#[test]
fn jacobi_strategies_ordering_on_hcl15() {
    // on the paper's 15-node cluster DFPA's sweeps beat Even's, and the
    // self-adaptation overhead stays a small fraction of the application
    let spec = presets::hcl15();
    let r_even = jacobi::run(&spec, &JacobiConfig::new(2048, Strategy::Even)).unwrap();
    let r_dfpa = jacobi::run(&spec, &JacobiConfig::new(2048, Strategy::Dfpa)).unwrap();
    assert!(
        r_dfpa.compute_s < r_even.compute_s,
        "dfpa {} vs even {}",
        r_dfpa.compute_s,
        r_even.compute_s
    );
    assert!(r_dfpa.partition_s < r_dfpa.total_s);
    assert_eq!(r_dfpa.d.iter().sum::<u64>(), 2048);
}

#[test]
fn jacobi_numerics_match_oracle_at_dfpa_distribution() {
    // the sliced sweep the app models is numerically the whole-grid sweep
    let spec = presets::mini4();
    let r = jacobi::run(&spec, &JacobiConfig::new(128, Strategy::Dfpa)).unwrap();
    assert_eq!(jacobi::verify_sweeps(128, &r.d, 3, 0xE2E), 0.0);
}

#[test]
fn jacobi_cold_then_warm_store_round_trip() {
    let dir = unique_temp_dir("e2e-jacobi-store");
    let spec = presets::mini4();
    let mut cfg = JacobiConfig::new(1024, Strategy::Dfpa);
    cfg.model_store = Some(dir.clone());
    let cold = jacobi::run(&spec, &cfg).unwrap();
    let warm = jacobi::run(&spec, &cfg).unwrap();
    assert!(!cold.warm_started && warm.warm_started);
    assert!(
        warm.iterations <= cold.iterations,
        "warm {} vs cold {}",
        warm.iterations,
        cold.iterations
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn lu_strategies_ordering_on_hcl15() {
    let spec = presets::hcl15();
    let mk = |s: Strategy| LuConfig::new(2048, s); // b=64 → 32 panels
    let r_even = lu::run(&spec, &mk(Strategy::Even)).unwrap();
    let r_dfpa = lu::run(&spec, &mk(Strategy::Dfpa)).unwrap();
    assert!(
        r_dfpa.compute_s < r_even.compute_s,
        "dfpa {} vs even {}",
        r_dfpa.compute_s,
        r_even.compute_s
    );
    assert_eq!(r_dfpa.panels, 32);
}

#[test]
fn lu_cold_then_warm_store_round_trip() {
    let dir = unique_temp_dir("e2e-lu-store");
    let spec = presets::mini4();
    let mut cfg = LuConfig::new(1024, Strategy::Dfpa);
    cfg.block = 32;
    cfg.model_store = Some(dir.clone());
    let cold = lu::run(&spec, &cfg).unwrap();
    let warm = lu::run(&spec, &cfg).unwrap();
    assert!(!cold.warm_started && warm.warm_started);
    assert!(
        warm.iterations <= cold.iterations,
        "warm {} vs cold {}",
        warm.iterations,
        cold.iterations
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn lu_numerics_match_oracle() {
    assert!(lu::verify_factorization(48, 8, 0xE2E) < 1e-8);
}

#[test]
fn real_pjrt_e2e_verified() {
    // the mandated end-to-end check: only runs when artifacts exist
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let spec = presets::mini4();
    let out = matmul1d::run_real_verified(&spec, 256, 0.2).unwrap();
    assert!(
        out.max_error < 1e-3,
        "verification failed: {}",
        out.max_error
    );
    assert!(out.kernel_execs > 0);
    assert_eq!(out.report.d.iter().sum::<u64>(), 256);
}
