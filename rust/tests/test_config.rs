//! Config-file integration: the shipped configs/ parse into the same
//! clusters as the presets.

use hfpm::config::{ClusterSpec, Document};
use std::path::Path;

#[test]
fn shipped_hcl_config_parses() {
    let path = Path::new("configs/hcl.toml");
    assert!(path.exists(), "configs/hcl.toml missing from the repo");
    let spec = ClusterSpec::load(path).unwrap();
    assert_eq!(spec.size(), 16);
    assert_eq!(spec.name, "hcl");
    // must agree with the in-code preset
    let preset = hfpm::cluster::presets::hcl();
    for (a, b) in spec.nodes.iter().zip(&preset.nodes) {
        assert_eq!(a.host, b.host);
        assert_eq!(a.ram_mib, b.ram_mib);
        assert_eq!(a.l2_kib, b.l2_kib);
        assert!((a.clock_ghz - b.clock_ghz).abs() < 1e-9);
    }
}

#[test]
fn shipped_mini4_config_parses() {
    let spec = ClusterSpec::load(Path::new("configs/mini4.toml")).unwrap();
    assert_eq!(spec.size(), 4);
}

#[test]
fn config_roundtrip_through_document() {
    let text = std::fs::read_to_string("configs/hcl.toml").unwrap();
    let doc = Document::parse(&text).unwrap();
    assert!(doc.table_arrays.contains_key("node"));
    assert_eq!(doc.table_arrays["node"].len(), 16);
}

#[test]
fn malformed_configs_rejected() {
    for bad in [
        "name = \"x\"\n",                        // no nodes
        "[[node]]\nhost = \"a\"\n",              // missing required keys
        "[[node]]\nclock_ghz = 3.0\n",           // missing host
    ] {
        let doc = Document::parse(bad).unwrap();
        assert!(
            ClusterSpec::from_document(&doc).is_err(),
            "accepted bad config: {bad}"
        );
    }
}
