//! Property-based invariants (testkit): randomized checks of the core
//! algorithms' contracts.

use hfpm::dfpa::algorithm::{even_distribution, run_dfpa, Benchmarker, DfpaOptions, StepReport};
use hfpm::error::Result;
use hfpm::fpm::{PiecewiseModel, SpeedFunction};
use hfpm::partition::{self, hsp};
use hfpm::testkit::{check, check_with, Config, Gen};
use hfpm::util::stats::max_relative_imbalance;
use hfpm::util::timer::VirtualClock;
use hfpm::{prop_assert, prop_assert_close};

/// Random piecewise model with decreasing-ish speeds (canonical shape).
fn random_model(g: &mut Gen) -> PiecewiseModel {
    let mut m = PiecewiseModel::new();
    let k = g.usize_in(1, 6);
    let mut x = g.f64_in(1.0, 50.0);
    let mut s = g.f64_in(100.0, 1000.0);
    for _ in 0..k {
        m.insert(x, s);
        x *= g.f64_in(1.5, 4.0);
        s *= g.f64_in(0.4, 1.0); // non-increasing speeds
    }
    m
}

#[test]
fn prop_partition_sums_and_nonneg() {
    check("partition: Σd = n, d ≥ 0", |g| {
        let p = g.usize_in(1, 12);
        let models: Vec<PiecewiseModel> = (0..p).map(|_| random_model(g)).collect();
        let n = g.u64_in(1, 100_000);
        let part = partition::partition(n, &models).map_err(|e| e.to_string())?;
        prop_assert!(part.d.len() == p, "wrong length");
        prop_assert!(
            part.d.iter().sum::<u64>() == n,
            "sum {} != {n}",
            part.d.iter().sum::<u64>()
        );
        Ok(())
    });
}

#[test]
fn prop_partition_locally_optimal() {
    // no single-unit move improves the makespan (within float slack)
    check_with(
        &Config {
            cases: 64,
            ..Default::default()
        },
        "partition: local optimality",
        |g| {
            let p = g.usize_in(2, 6);
            let models: Vec<PiecewiseModel> = (0..p).map(|_| random_model(g)).collect();
            let n = g.u64_in(p as u64, 20_000);
            let part = partition::partition(n, &models).map_err(|e| e.to_string())?;
            let makespan = |d: &[u64]| -> f64 {
                d.iter()
                    .zip(&models)
                    .map(|(&x, m)| if x == 0 { 0.0 } else { m.time(x as f64) })
                    .fold(0.0f64, f64::max)
            };
            let base = makespan(&part.d);
            for src in 0..p {
                if part.d[src] == 0 {
                    continue;
                }
                for dst in 0..p {
                    if src == dst {
                        continue;
                    }
                    let mut alt = part.d.clone();
                    alt[src] -= 1;
                    alt[dst] += 1;
                    prop_assert!(
                        makespan(&alt) >= base * (1.0 - 1e-9),
                        "move {src}->{dst}: {} < {base}",
                        makespan(&alt)
                    );
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_round_to_sum_within_one_unit() {
    check("hsp: rounding stays within 1 of the reals", |g| {
        let p = g.usize_in(1, 16);
        let reals: Vec<f64> = (0..p).map(|_| g.f64_in(0.0, 1e5)).collect();
        let total: f64 = reals.iter().sum();
        let n = total.round() as u64;
        let d = hsp::round_to_sum(&reals, n);
        prop_assert!(d.iter().sum::<u64>() == n, "sum mismatch");
        for (i, (&di, &ri)) in d.iter().zip(&reals).enumerate() {
            // largest-remainder keeps each within ~1 of its real (plus the
            // global overshoot correction, ≤ p extra in pathological cases)
            prop_assert!(
                (di as f64 - ri).abs() <= 1.0 + p as f64,
                "entry {i}: {di} vs {ri}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_even_distribution_within_one() {
    check("even distribution: |d_i − n/p| < 1", |g| {
        let p = g.usize_in(1, 40);
        let n = g.u64_in(0, 1_000_000);
        let d = even_distribution(n, p);
        prop_assert!(d.iter().sum::<u64>() == n, "sum");
        let lo = n / p as u64;
        for &x in &d {
            prop_assert!(x == lo || x == lo + 1, "{x} not in {{{lo}, {}}}", lo + 1);
        }
        Ok(())
    });
}

#[test]
fn prop_piecewise_eval_bounded_by_observations() {
    check("piecewise: eval within [min_s, max_s]", |g| {
        let m = random_model(g);
        let (min_s, max_s) = m
            .points()
            .iter()
            .fold((f64::MAX, f64::MIN), |(lo, hi), p| {
                (lo.min(p.s), hi.max(p.s))
            });
        for _ in 0..50 {
            let x = g.f64_in(0.1, 1e6);
            let s = m.speed(x);
            prop_assert!(
                s >= min_s - 1e-9 && s <= max_s + 1e-9,
                "speed({x}) = {s} outside [{min_s}, {max_s}]"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_virtual_clock_monotone() {
    check("virtual clock: monotone under any op sequence", |g| {
        let mut c = VirtualClock::new();
        let mut last = 0.0;
        for _ in 0..g.usize_in(1, 100) {
            match g.usize_in(0, 2) {
                0 => c.advance(g.f64_in(0.0, 10.0)),
                1 => {
                    let durs = g.vec_f64(0, 5, 0.0, 10.0);
                    c.join_parallel(&durs);
                }
                _ => c.sync_to(g.f64_in(0.0, 500.0)),
            }
            prop_assert!(c.now() >= last, "clock went backwards");
            last = c.now();
        }
        Ok(())
    });
}

/// Deterministic analytic benchmarker for DFPA properties.
struct PropBench {
    models: Vec<PiecewiseModel>,
}

impl Benchmarker for PropBench {
    fn processors(&self) -> usize {
        self.models.len()
    }
    fn run_parallel(&mut self, d: &[u64]) -> Result<StepReport> {
        let times: Vec<f64> = d
            .iter()
            .zip(&self.models)
            .map(|(&x, m)| if x == 0 { 0.0 } else { m.time(x as f64) })
            .collect();
        let max = times.iter().cloned().fold(0.0f64, f64::max);
        Ok(StepReport {
            times,
            virtual_cost_s: max,
        })
    }
}

#[test]
fn prop_dfpa_exit_criterion_holds() {
    // whenever DFPA reports converged, the returned times satisfy ε
    check_with(
        &Config {
            cases: 48,
            ..Default::default()
        },
        "dfpa: ε holds at exit",
        |g| {
            let p = g.usize_in(2, 8);
            let models: Vec<PiecewiseModel> = (0..p).map(|_| random_model(g)).collect();
            let n = g.u64_in(100 * p as u64, 200_000);
            let eps = g.f64_in(0.02, 0.2);
            let mut bench = PropBench { models };
            let r = run_dfpa(n, &mut bench, DfpaOptions::with_epsilon(eps))
                .map_err(|e| e.to_string())?;
            prop_assert!(r.d.iter().sum::<u64>() == n, "sum");
            if r.converged {
                let active: Vec<f64> = r
                    .times
                    .iter()
                    .zip(&r.d)
                    .filter(|(_, &d)| d > 0)
                    .map(|(&t, _)| t)
                    .collect();
                let imb = max_relative_imbalance(&active);
                prop_assert!(imb <= eps + 1e-9, "imbalance {imb} > ε {eps}");
            }
            Ok(())
        },
    );
}

#[test]
fn prop_dfpa_gather_heard_every_worker_once() {
    // routing/batching invariant: every iteration's record has exactly one
    // observation per processor and distributions always sum to n
    check_with(
        &Config {
            cases: 32,
            ..Default::default()
        },
        "dfpa: per-iteration records complete",
        |g| {
            let p = g.usize_in(2, 6);
            let models: Vec<PiecewiseModel> = (0..p).map(|_| random_model(g)).collect();
            let n = g.u64_in(10 * p as u64, 50_000);
            let mut bench = PropBench { models };
            let r = run_dfpa(n, &mut bench, DfpaOptions::with_epsilon(0.05))
                .map_err(|e| e.to_string())?;
            prop_assert!(!r.records.is_empty(), "no records");
            for rec in &r.records {
                prop_assert!(rec.d.len() == p, "d width");
                prop_assert!(rec.times.len() == p, "times width");
                prop_assert!(rec.d.iter().sum::<u64>() == n, "iteration sum");
            }
            // virtual accounting consistency
            let total: f64 = r.records.iter().map(|rec| rec.virtual_cost_s).sum();
            prop_assert_close!(total, r.total_virtual_s, 1e-9);
            Ok(())
        },
    );
}

#[test]
fn prop_scaled_model_time_invariant() {
    check("scaled model: time is unit-change invariant", |g| {
        let m = random_model(g);
        let scale = g.f64_in(2.0, 1000.0);
        let view = hfpm::fpm::ScaledModel::new(&m, scale);
        for _ in 0..20 {
            let rows = g.f64_in(0.5, 1e4);
            prop_assert_close!(view.time(rows), m.time(rows * scale), 1e-6 * m.time(rows * scale).max(1.0));
        }
        Ok(())
    });
}
