//! PJRT runtime integration tests — gated on `make artifacts` having run
//! (they skip, loudly, otherwise; `make test` always builds artifacts
//! first).

use hfpm::apps::workload::{matmul_ref, max_abs_diff, Matrix};
use hfpm::runtime::{ArtifactManifest, PjrtEngine, PjrtService};
use std::path::Path;

fn manifest() -> Option<ArtifactManifest> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping runtime test: run `make artifacts` first");
        return None;
    }
    Some(ArtifactManifest::load(dir).unwrap())
}

#[test]
fn every_artifact_compiles_and_runs() {
    let Some(m) = manifest() else { return };
    let mut engine = PjrtEngine::new(m.clone()).unwrap();
    for a in &m.artifacts {
        // build correctly-shaped dummy inputs per kind
        let inputs: Vec<(Vec<f32>, Vec<usize>)> = match a.kind {
            hfpm::runtime::ArtifactKind::Matmul1d => {
                let (nb, n) = (a.dims[0] as usize, a.dims[1] as usize);
                vec![
                    (vec![0.5; nb * n], vec![nb, n]),
                    (vec![0.5; n * n], vec![n, n]),
                ]
            }
            hfpm::runtime::ArtifactKind::Rank1 => {
                let (nb, n) = (a.dims[0] as usize, a.dims[1] as usize);
                vec![
                    (vec![0.0; nb * n], vec![nb, n]),
                    (vec![1.0; nb], vec![nb, 1]),
                    (vec![1.0; n], vec![1, n]),
                ]
            }
            hfpm::runtime::ArtifactKind::Block2d => {
                let (mb, nb, t) = (
                    a.dims[0] as usize,
                    a.dims[1] as usize,
                    a.dims[2] as usize,
                );
                vec![
                    (vec![0.0; mb * nb], vec![mb, nb]),
                    (vec![1.0; mb * t], vec![mb, t]),
                    (vec![1.0; t * nb], vec![t, nb]),
                ]
            }
        };
        let refs: Vec<(&[f32], &[usize])> = inputs
            .iter()
            .map(|(d, s)| (d.as_slice(), s.as_slice()))
            .collect();
        let (out, dt) = engine
            .execute_f32(&a.name, &refs)
            .unwrap_or_else(|e| panic!("artifact {} failed: {e}", a.name));
        assert!(!out.is_empty(), "{}: empty output", a.name);
        assert!(dt > 0.0);
    }
    assert_eq!(engine.cached(), m.artifacts.len());
}

#[test]
fn pjrt_matmul_matches_naive_oracle() {
    let Some(m) = manifest() else { return };
    let mut engine = PjrtEngine::new(m).unwrap();
    let nb = 128usize;
    let n = 256usize;
    let a = Matrix::random(nb, n, 21);
    let b = Matrix::random(n, n, 22);
    let (out, _) = engine
        .execute_f32(
            "matmul_nb128_n256",
            &[(&a.data, &[nb, n]), (&b.data, &[n, n])],
        )
        .unwrap();
    let got = Matrix {
        rows: nb,
        cols: n,
        data: out,
    };
    let want = matmul_ref(&a, &b);
    let err = max_abs_diff(&got, &want);
    assert!(err < 1e-3, "PJRT vs naive oracle: max err {err}");
}

#[test]
fn rank1_chain_equals_matmul() {
    // n rank-1 updates through PJRT == one matmul: the identity the 1D
    // app is built on, verified through the real runtime
    let Some(m) = manifest() else { return };
    let mut engine = PjrtEngine::new(m).unwrap();
    let nb = 64usize;
    let n = 512usize;
    let k = 16usize; // chain length (full n would be slow in a unit test)
    let a = Matrix::random(nb, k, 31);
    let b = Matrix::random(k, n, 32);
    let mut c = vec![0.0f32; nb * n];
    for t in 0..k {
        let a_col: Vec<f32> = (0..nb).map(|r| a.data[r * k + t]).collect();
        let b_row: Vec<f32> = b.data[t * n..(t + 1) * n].to_vec();
        let (out, _) = engine
            .execute_f32(
                "update_nb64_n512",
                &[(&c, &[nb, n]), (&a_col, &[nb, 1]), (&b_row, &[1, n])],
            )
            .unwrap();
        c = out;
    }
    let got = Matrix {
        rows: nb,
        cols: n,
        data: c,
    };
    let want = matmul_ref(&a, &Matrix { rows: k, cols: n, data: b.data.clone() });
    let err = max_abs_diff(&got, &want);
    assert!(err < 1e-3, "rank-1 chain vs matmul: max err {err}");
}

#[test]
fn service_calibration_produces_rates() {
    let Some(m) = manifest() else { return };
    let svc = PjrtService::start(m.clone()).unwrap();
    svc.calibrate_rank1(2).unwrap();
    for a in m
        .artifacts
        .iter()
        .filter(|a| a.kind == hfpm::runtime::ArtifactKind::Rank1)
    {
        let rate = svc.known_rate(&a.name);
        assert!(rate.is_some(), "no rate for {}", a.name);
        assert!(rate.unwrap() > 1e6, "implausible rate {:?}", rate);
    }
}

#[test]
fn manifest_covers_required_kinds() {
    let Some(m) = manifest() else { return };
    use hfpm::runtime::ArtifactKind::*;
    for kind in [Matmul1d, Rank1, Block2d] {
        assert!(
            m.artifacts.iter().any(|a| a.kind == kind),
            "manifest missing {kind:?} artifacts"
        );
    }
}
