//! Integration tests: partitioning algorithms against realistic models.

use hfpm::config::MachineSpec;
use hfpm::fpm::analytic::{AnalyticModel, Footprint};
use hfpm::fpm::{ConstantModel, PiecewiseModel, ScaledModel, SpeedFunction};
use hfpm::partition::{self, cpm, grid2d, hsp};

fn hcl_like_models(n: usize) -> Vec<AnalyticModel> {
    let fp = Footprint::matmul_1d(n);
    [
        (3.4, 800.0, 0.30, 1024, 1024),
        (1.8, 1000.0, 0.55, 1024, 1024),
        (3.6, 800.0, 0.30, 2048, 256),
        (2.9, 533.0, 0.22, 256, 512),
    ]
    .iter()
    .map(|&(ghz, bus, upc, l2, ram)| {
        AnalyticModel::from_spec(&MachineSpec::new("x", "", ghz, bus, upc, l2, ram), fp)
    })
    .collect()
}

#[test]
fn geometric_balances_analytic_cluster() {
    let models = hcl_like_models(4096);
    let rows = 4096u64;
    let views: Vec<ScaledModel<&AnalyticModel>> = models
        .iter()
        .map(|m| ScaledModel::new(m, 4096.0))
        .collect();
    let part = partition::partition(rows, &views).unwrap();
    assert_eq!(part.d.iter().sum::<u64>(), rows);
    let times: Vec<f64> = part
        .d
        .iter()
        .zip(&views)
        .map(|(&d, m)| m.time(d as f64))
        .collect();
    let imb = hfpm::util::stats::max_relative_imbalance(&times);
    assert!(imb < 0.02, "imbalance {imb} for d={:?}", part.d);
}

#[test]
fn geometric_protects_paging_node() {
    // at n=5120 the 256 MiB node pages if given an even share
    let models = hcl_like_models(5120);
    let views: Vec<ScaledModel<&AnalyticModel>> = models
        .iter()
        .map(|m| ScaledModel::new(m, 5120.0))
        .collect();
    let part = partition::partition(5120, &views).unwrap();
    // node 2 (256 MiB) must get far fewer rows than the even share — the
    // equal-time optimum may sit slightly inside its paging region, but
    // never anywhere near an even split
    assert!(
        part.d[2] < (5120 / 4) * 6 / 10,
        "paging node got {} rows (even share is {})",
        part.d[2],
        5120 / 4
    );
    // and the resulting times must still be balanced
    let times: Vec<f64> = part
        .d
        .iter()
        .zip(&views)
        .map(|(&d, m)| m.time(d as f64))
        .collect();
    let imb = hfpm::util::stats::max_relative_imbalance(&times);
    assert!(imb < 0.05, "imbalance {imb}");
}

#[test]
fn geometric_scales_to_many_processors() {
    // 128 processors with random-ish constant speeds: O(p log n) must be fast
    let models: Vec<ConstantModel> = (0..128)
        .map(|i| ConstantModel(50.0 + (i * 37 % 100) as f64))
        .collect();
    let sw = std::time::Instant::now();
    let part = partition::partition(1_000_000, &models).unwrap();
    assert_eq!(part.d.iter().sum::<u64>(), 1_000_000);
    assert!(sw.elapsed().as_millis() < 500, "too slow: {:?}", sw.elapsed());
    // proportionality sanity: fastest gets ~3x the slowest
    let (min_s, max_s) = (50.0, 149.0);
    let min_d = *part.d.iter().min().unwrap() as f64;
    let max_d = *part.d.iter().max().unwrap() as f64;
    let ratio = max_d / min_d;
    assert!((ratio - max_s / min_s).abs() < 0.3, "ratio {ratio}");
}

#[test]
fn cpm_vs_geometric_agree_for_constant_models() {
    let speeds = [13.0, 29.0, 58.0];
    let cpm_d = cpm::partition_proportional(10_000, &speeds).unwrap();
    let models: Vec<ConstantModel> = speeds.iter().map(|&s| ConstantModel(s)).collect();
    let geo = partition::partition(10_000, &models).unwrap();
    assert_eq!(cpm_d, geo.d);
}

#[test]
fn refinement_never_worsens_and_usually_improves() {
    // refine is move-bounded (4p), so from a *distant* start it may not
    // reach the local optimum — but it must never worsen the makespan,
    // and from this imbalanced start it must strictly improve.
    // (Full local optimality from the partitioner's own output is covered
    // by props_invariants::prop_partition_locally_optimal.)
    let models = hcl_like_models(2048);
    let views: Vec<ScaledModel<&AnalyticModel>> = models
        .iter()
        .map(|m| ScaledModel::new(m, 2048.0))
        .collect();
    let start = hsp::round_to_sum(&[600.0, 700.0, 400.0, 348.0], 2048);
    let makespan = |d: &[u64]| -> f64 {
        d.iter()
            .zip(&views)
            .map(|(&x, m)| if x == 0 { 0.0 } else { m.time(x as f64) })
            .fold(0.0f64, f64::max)
    };
    let before = makespan(&start);
    let mut d = start.clone();
    hsp::refine(&mut d, &views);
    let after = makespan(&d);
    assert!(after <= before + 1e-12, "refine worsened: {after} > {before}");
    assert!(after < before, "refine made no progress from a bad start");
    assert_eq!(d.iter().sum::<u64>(), 2048);
}

#[test]
fn two_step_matches_manual_computation() {
    // independent check of the Fig 8 example with exact fractions
    let speeds = vec![
        vec![0.11, 0.25, 0.05],
        vec![0.17, 0.09, 0.08],
        vec![0.05, 0.17, 0.03],
    ];
    let g = grid2d::two_step(6, 6, &speeds).unwrap();
    assert_eq!(g.total_area(), 36);
    // every processor owns a contiguous rectangle; areas roughly ∝ speed
    let total_speed: f64 = speeds.iter().flatten().sum();
    for i in 0..3 {
        for j in 0..3 {
            let area = g.area(i, j) as f64 / 36.0;
            let frac = speeds[i][j] / total_speed;
            assert!(
                (area - frac).abs() < 0.12,
                "P{i}{j}: area {area:.2} vs speed {frac:.2}"
            );
        }
    }
}

#[test]
fn piecewise_estimate_converges_to_truth_partition() {
    // a dense piecewise estimate of an analytic model partitions (almost)
    // identically to the analytic model itself
    let models = hcl_like_models(3072);
    let grid = hfpm::fpm::builder::log_grid(1e4, 4e7, 60);
    let (estimates, _) = hfpm::fpm::builder::build_exact_models(&models, &grid);
    let views_t: Vec<ScaledModel<&AnalyticModel>> =
        models.iter().map(|m| ScaledModel::new(m, 3072.0)).collect();
    let views_e: Vec<ScaledModel<&PiecewiseModel>> = estimates
        .iter()
        .map(|m| ScaledModel::new(m, 3072.0))
        .collect();
    let dt = partition::partition(3072, &views_t).unwrap().d;
    let de = partition::partition(3072, &views_e).unwrap().d;
    for (a, b) in dt.iter().zip(&de) {
        let diff = a.abs_diff(*b);
        assert!(diff <= 3072 / 50, "truth {a} vs estimate {b}");
    }
}
