//! The bi-objective distributor: golden Pareto cases, DFPA equivalence at
//! w = 1, dual-family store round trips, and the energy-aware workloads.

use hfpm::adapt::{AdaptiveSession, Dfpa, Distributor, Observations, SessionCtx, Strategy};
use hfpm::apps::matmul1d::{self, Matmul1dConfig};
use hfpm::apps::{jacobi, JacobiConfig};
use hfpm::biobj::BiObj;
use hfpm::cluster::presets;
use hfpm::modelstore::{ModelKey, ModelStore};
use hfpm::testkit::{unique_temp_dir, ConstEnergyBench as EnergyBench};

/// Deterministic 2-processor "cluster": equal constant speeds, a 5× gap in
/// energy per unit — the time-optimal and energy-optimal distributions
/// provably differ ([n/2, n/2] vs [0, n]).
fn golden() -> EnergyBench {
    EnergyBench::new(&[10.0, 10.0], &[5.0, 1.0])
}

#[test]
fn golden_front_is_non_dominated_and_spans_the_tradeoff() {
    let mut bench = golden();
    let out = BiObj::new(0.5)
        .distribute(1000, &mut bench, &SessionCtx::with_epsilon(0.05))
        .unwrap();
    let front = out.pareto.expect("metered run reports a front");
    assert!(front.len() >= 2, "front collapsed: {front:?}");
    // time-ascending and energy-descending ⇒ pairwise non-dominated
    for w in front.points.windows(2) {
        assert!(w[0].0 < w[1].0, "times not increasing: {front:?}");
        assert!(w[0].1 > w[1].1, "energies not decreasing: {front:?}");
    }
    let (t_lo, t_hi) = front.time_range_s();
    let (e_lo, e_hi) = front.energy_range_j();
    assert!(t_hi > t_lo && e_hi > e_lo);
}

#[test]
fn weight_one_matches_dfpa_exactly_on_a_deterministic_bench() {
    // the acceptance bar: biobj:1.0 must reproduce dfpa's distribution —
    // noise-free constant speeds make the match exact, since both refine
    // the same models and re-partition with the same geometric kernel
    let speeds = [10.0, 30.0, 20.0];
    let mut dfpa_bench = EnergyBench::new(&speeds, &[1.0, 1.0, 1.0]);
    let d_dfpa = Dfpa::default()
        .distribute(600, &mut dfpa_bench, &SessionCtx::with_epsilon(0.02))
        .unwrap()
        .distribution
        .into_1d()
        .unwrap();

    let mut bi_bench = EnergyBench::new(&speeds, &[1.0, 1.0, 1.0]);
    let out = BiObj::new(1.0)
        .distribute(600, &mut bi_bench, &SessionCtx::with_epsilon(0.02))
        .unwrap();
    assert!(out.converged);
    assert_eq!(out.distribution.into_1d().unwrap(), d_dfpa);
}

#[test]
fn weight_zero_shifts_load_to_the_efficient_processor() {
    let mut bench = golden();
    let time_opt = BiObj::new(1.0)
        .distribute(1000, &mut bench, &SessionCtx::with_epsilon(0.05))
        .unwrap()
        .distribution
        .into_1d()
        .unwrap();
    let mut bench = golden();
    let energy_opt = BiObj::new(0.0)
        .distribute(1000, &mut bench, &SessionCtx::with_epsilon(0.05))
        .unwrap()
        .distribution
        .into_1d()
        .unwrap();
    assert_ne!(time_opt, energy_opt, "objectives must disagree here");
    assert!(energy_opt[1] > time_opt[1], "w=0 must load the cheap node");
    // under the bench's ground truth the energy ordering is strict
    let e = |d: &[u64]| d[0] as f64 * 5.0 + d[1] as f64 * 1.0;
    assert!(e(&energy_opt) < e(&time_opt));
}

#[test]
fn session_round_trip_warm_starts_both_function_families() {
    let dir = unique_temp_dir("biobj-store");
    let keys: Vec<ModelKey> = (0..2)
        .map(|i| ModelKey::new(&format!("node{i}"), "biobj_test", "sim"))
        .collect();
    let session = AdaptiveSession::new()
        .epsilon(0.05)
        .model_store(Some(dir.clone()));

    let mut dist = BiObj::new(0.5);
    let cold = {
        let mut bench = golden();
        session.run_1d(&mut dist, 2000, &mut bench, &keys).unwrap()
    };
    assert!(!cold.warm_started && !cold.warm_started_energy);
    assert!(matches!(&cold.energy_observations, Observations::OneD(_)));

    // the flush wrote BOTH families: plain keys and #energy keys
    let store = ModelStore::open(&dir).unwrap();
    let entries = store.entries().unwrap();
    let plain = entries.iter().filter(|k| !k.is_energy()).count();
    let energetic = entries.iter().filter(|k| k.is_energy()).count();
    assert!(plain >= 1, "speed family missing: {entries:?}");
    assert!(energetic >= 1, "energy family missing: {entries:?}");
    drop(store); // release the advisory lock before the warm run

    let warm = {
        let mut bench = golden();
        session.run_1d(&mut dist, 2000, &mut bench, &keys).unwrap()
    };
    assert!(warm.warm_started, "speed family must warm-start");
    assert!(warm.warm_started_energy, "energy family must warm-start");
    assert!(
        warm.benchmark_steps < cold.benchmark_steps,
        "warm {} vs cold {}",
        warm.benchmark_steps,
        cold.benchmark_steps
    );
    let _ = std::fs::remove_dir_all(&dir);
}

// --------------------------------------------------------------------------
// App-level acceptance on the simulated clusters (joules metered by the
// nodes' power profiles)
// --------------------------------------------------------------------------

fn strategy(s: &str) -> Strategy {
    Strategy::parse(s).unwrap()
}

#[test]
fn app_biobj_pure_energy_beats_dfpa_on_energy() {
    // mini4's p1 (3.4 GHz NetBurst-ish) and p2 (1.8 GHz high-IPC) are
    // near-equally fast but ~6× apart in joules per unit, so the
    // energy-optimal split genuinely differs from the time-optimal one
    let spec = presets::mini4();
    let mut cfg_dfpa = Matmul1dConfig::new(2048, Strategy::Dfpa);
    cfg_dfpa.epsilon = 0.05;
    let r_dfpa = matmul1d::run(&spec, &cfg_dfpa).unwrap();

    let mut cfg_bi = Matmul1dConfig::new(2048, strategy("biobj:0.0"));
    cfg_bi.epsilon = 0.05;
    let r_bi = matmul1d::run(&spec, &cfg_bi).unwrap();

    assert_eq!(r_bi.d.iter().sum::<u64>(), 2048);
    assert!(
        r_bi.energy_j < r_dfpa.energy_j,
        "biobj:0.0 {} J vs dfpa {} J",
        r_bi.energy_j,
        r_dfpa.energy_j
    );
    assert!(r_bi.pareto.is_some(), "biobj reports its front");
}

#[test]
fn app_biobj_pure_time_tracks_dfpa_within_epsilon() {
    let spec = presets::mini4();
    let mut cfg_dfpa = Matmul1dConfig::new(2048, Strategy::Dfpa);
    cfg_dfpa.epsilon = 0.05;
    let r_dfpa = matmul1d::run(&spec, &cfg_dfpa).unwrap();

    let mut cfg_bi = Matmul1dConfig::new(2048, strategy("biobj:1.0"));
    cfg_bi.epsilon = 0.05;
    let r_bi = matmul1d::run(&spec, &cfg_bi).unwrap();

    // same objective, same partitioner ⇒ the compute phases agree to
    // within the termination accuracy (plus simulator noise headroom)
    let rel = (r_bi.compute_s - r_dfpa.compute_s).abs() / r_dfpa.compute_s;
    assert!(
        rel <= 3.0 * 0.05,
        "biobj:1.0 compute {} vs dfpa {} (rel {rel})",
        r_bi.compute_s,
        r_dfpa.compute_s
    );
}

#[test]
fn app_jacobi_runs_energy_aware_end_to_end() {
    // the registry entry opens the iterative workloads to energy-aware
    // operation without app changes
    let spec = presets::mini4();
    let mut cfg = JacobiConfig::new(512, strategy("biobj:0.5"));
    cfg.sweeps = 8;
    cfg.rebalance_every = 4;
    let r = jacobi::run(&spec, &cfg).unwrap();
    assert_eq!(r.d.iter().sum::<u64>(), 512);
    assert_eq!(r.sweeps, 8);
    assert!(r.energy_j > 0.0);
    assert!(r.pareto.is_some(), "jacobi surfaces the biobj front");
}

#[test]
fn store_strategies_report_energy_consistently() {
    // dfpa on a metered cluster reports joules too (from the cluster's
    // joule clock), with no pareto front
    let spec = presets::mini4();
    let dir = unique_temp_dir("biobj-vs-dfpa-store");
    let mut cfg = Matmul1dConfig::new(1024, strategy("biobj:0.5"));
    cfg.model_store = Some(dir.clone());
    let cold = matmul1d::run(&spec, &cfg).unwrap();
    assert!(!cold.warm_started);
    let warm = matmul1d::run(&spec, &cfg).unwrap();
    assert!(warm.warm_started && warm.warm_started_energy);
    assert!(
        warm.iterations <= cold.iterations,
        "warm {} vs cold {}",
        warm.iterations,
        cold.iterations
    );
    // both families persisted under this app's kernel keys
    let store = ModelStore::open(&dir).unwrap();
    let entries = store.entries().unwrap();
    assert!(entries.iter().any(|k| k.is_energy()));
    assert!(entries.iter().any(|k| !k.is_energy()));
    let _ = std::fs::remove_dir_all(&dir);
}
