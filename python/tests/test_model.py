"""L2 model checks: lowering, bucket family, padding."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model


class TestLocalMatmul:
    def test_equals_numpy(self):
        rng = np.random.default_rng(0)
        a = rng.standard_normal((128, 256)).astype(np.float32)
        b = rng.standard_normal((256, 256)).astype(np.float32)
        got = model.local_matmul(jnp.asarray(a), jnp.asarray(b))
        np.testing.assert_allclose(np.asarray(got), a @ b, rtol=1e-4, atol=1e-3)

    def test_app_identity_full_multiply(self):
        # the distributed app computes C = A @ B by slicing rows: any row
        # partition of A must reassemble to the full product
        rng = np.random.default_rng(1)
        n = 256
        a = rng.standard_normal((n, n)).astype(np.float32)
        b = rng.standard_normal((n, n)).astype(np.float32)
        c_full = np.asarray(model.local_matmul(jnp.asarray(a), jnp.asarray(b)))
        c_parts = [
            np.asarray(model.local_matmul(jnp.asarray(a[lo:hi]), jnp.asarray(b)))
            for lo, hi in [(0, 64), (64, 192), (192, 256)]
        ]
        np.testing.assert_allclose(np.vstack(c_parts), c_full, rtol=1e-5)


class TestPadding:
    @settings(max_examples=20, deadline=None)
    @given(r=st.integers(1, 100), c=st.integers(1, 100), seed=st.integers(0, 999))
    def test_pad_preserves_content(self, r, c, seed):
        rng = np.random.default_rng(seed)
        x = rng.standard_normal((r, c)).astype(np.float32)
        p = model.pad_to(jnp.asarray(x), 128, 128)
        assert p.shape == (128, 128)
        np.testing.assert_array_equal(np.asarray(p)[:r, :c], x)
        assert float(jnp.abs(p[r:, :]).max() if r < 128 else 0.0) == 0.0

    def test_pad_rejects_shrink(self):
        with pytest.raises(AssertionError):
            model.pad_to(jnp.zeros((10, 10)), 5, 20)

    def test_padded_matmul_matches_trimmed(self):
        # padding A with zero rows only appends zero rows to C — this is
        # the property the rust runtime's bucket-fit relies on
        rng = np.random.default_rng(3)
        a = rng.standard_normal((100, 256)).astype(np.float32)
        b = rng.standard_normal((256, 256)).astype(np.float32)
        ap = model.pad_to(jnp.asarray(a), 128, 256)
        c = np.asarray(model.local_matmul(ap, jnp.asarray(b)))
        np.testing.assert_allclose(c[:100], a @ b, rtol=1e-4, atol=1e-3)
        assert np.abs(c[100:]).max() == 0.0


class TestBuckets:
    def test_bucket_shapes_divisible_by_blocks(self):
        from compile.kernels.matmul import block_shape

        for nb, n in model.MATMUL_BUCKETS:
            bm, bk, bn = block_shape(nb, n, n)
            assert nb % bm == 0 and n % bk == 0 and n % bn == 0

    def test_buckets_sorted_and_unique(self):
        assert len(set(model.MATMUL_BUCKETS)) == len(model.MATMUL_BUCKETS)
        assert len(set(model.UPDATE_BUCKETS)) == len(model.UPDATE_BUCKETS)


class TestLowering:
    def test_local_matmul_lowers(self):
        lowered = model.lower_local_matmul(64, 256)
        text = str(lowered.compiler_ir("stablehlo"))
        assert "stablehlo" in text or "func.func" in text

    def test_rank1_lowers(self):
        lowered = model.lower_rank1_update(64, 512)
        assert lowered is not None

    def test_block_update_lowers(self):
        lowered = model.lower_block_update(128, 128, 64)
        assert lowered is not None
