"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (multiples of the block edge, plus sub-block
sizes) and dtypes; every case asserts allclose against ref.py. This is the
core correctness signal for the AOT artifacts the rust runtime executes.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import block_update, matmul_kernel, rank1_update
from compile.kernels.matmul import MXU_TILE, block_shape, vmem_bytes
from compile.kernels.ref import block_update_ref, matmul_ref, rank1_update_ref

# dimension strategy: sub-block sizes and multiples of the 128 tile
_dims = st.sampled_from([8, 16, 32, 64, 128, 256, 384, 512])
_dtypes = st.sampled_from([np.float32, jnp.bfloat16])


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype=dtype)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 1e-4


class TestMatmulKernel:
    @settings(max_examples=25, deadline=None)
    @given(m=_dims, k=_dims, n=_dims, dtype=_dtypes, seed=st.integers(0, 2**16))
    def test_matches_ref(self, m, k, n, dtype, seed):
        a = _rand((m, k), dtype, seed)
        b = _rand((k, n), dtype, seed + 1)
        got = matmul_kernel(a, b)
        want = matmul_ref(a, b)
        np.testing.assert_allclose(
            np.asarray(got, np.float32),
            np.asarray(want, np.float32),
            rtol=_tol(dtype),
            atol=_tol(dtype) * k,
        )

    def test_identity(self):
        eye = jnp.eye(128, dtype=jnp.float32)
        x = _rand((128, 128), np.float32, 7)
        np.testing.assert_allclose(np.asarray(matmul_kernel(eye, x)), np.asarray(x), rtol=1e-6)

    def test_zeros(self):
        z = jnp.zeros((256, 128), jnp.float32)
        b = _rand((128, 256), np.float32, 9)
        assert float(jnp.abs(matmul_kernel(z, b)).max()) == 0.0

    def test_rejects_mismatched_inner(self):
        a = jnp.zeros((64, 32), jnp.float32)
        b = jnp.zeros((64, 64), jnp.float32)
        with pytest.raises(AssertionError):
            matmul_kernel(a, b)

    def test_rejects_nondivisible(self):
        # 200 is not a multiple of the 128 block edge used for dim > 128
        a = jnp.zeros((200, 128), jnp.float32)
        b = jnp.zeros((128, 128), jnp.float32)
        with pytest.raises(AssertionError):
            matmul_kernel(a, b)

    def test_block_shape_caps_at_tile(self):
        assert block_shape(1024, 1024, 1024) == (MXU_TILE,) * 3
        assert block_shape(64, 32, 16) == (64, 32, 16)

    def test_vmem_budget(self):
        # the default tiling must leave room for double buffering in ~16MiB
        assert vmem_bytes(4096, 4096, 4096) <= 2 * 1024 * 1024


class TestRank1Update:
    @settings(max_examples=25, deadline=None)
    @given(nb=_dims, n=_dims, dtype=_dtypes, seed=st.integers(0, 2**16))
    def test_matches_ref(self, nb, n, dtype, seed):
        c = _rand((nb, n), dtype, seed)
        a = _rand((nb, 1), dtype, seed + 1)
        b = _rand((1, n), dtype, seed + 2)
        got = rank1_update(c, a, b)
        want = rank1_update_ref(c, a, b)
        np.testing.assert_allclose(
            np.asarray(got, np.float32),
            np.asarray(want, np.float32),
            rtol=_tol(dtype),
            atol=_tol(dtype),
        )

    def test_zero_vectors_noop(self):
        c = _rand((64, 128), np.float32, 3)
        a = jnp.zeros((64, 1), jnp.float32)
        b = jnp.zeros((1, 128), jnp.float32)
        np.testing.assert_array_equal(np.asarray(rank1_update(c, a, b)), np.asarray(c))

    def test_accumulation_composes(self):
        # n rank-1 updates == one matmul (the paper's app identity)
        nb, n, k = 32, 64, 8
        a = _rand((nb, k), np.float32, 11)
        b = _rand((k, n), np.float32, 12)
        c = jnp.zeros((nb, n), jnp.float32)
        for t in range(k):
            c = rank1_update(c, a[:, t : t + 1], b[t : t + 1, :])
        np.testing.assert_allclose(np.asarray(c), np.asarray(a @ b), rtol=1e-4, atol=1e-4)


class TestBlockUpdate:
    @settings(max_examples=20, deadline=None)
    @given(mb=_dims, nb=_dims, t=st.sampled_from([8, 64, 128, 256]),
           dtype=_dtypes, seed=st.integers(0, 2**16))
    def test_matches_ref(self, mb, nb, t, dtype, seed):
        c = _rand((mb, nb), dtype, seed)
        a = _rand((mb, t), dtype, seed + 1)
        b = _rand((t, nb), dtype, seed + 2)
        got = block_update(c, a, b)
        want = block_update_ref(c, a, b)
        np.testing.assert_allclose(
            np.asarray(got, np.float32),
            np.asarray(want, np.float32),
            rtol=_tol(dtype),
            atol=_tol(dtype) * t,
        )

    def test_shape_mismatch_rejected(self):
        with pytest.raises(AssertionError):
            block_update(
                jnp.zeros((64, 64), jnp.float32),
                jnp.zeros((64, 32), jnp.float32),
                jnp.zeros((16, 64), jnp.float32),
            )
