"""AOT bridge checks: HLO text emission and manifest integrity."""

import os

import numpy as np

import jax.numpy as jnp

from compile import aot, model


class TestHloText:
    def test_matmul_hlo_text_well_formed(self):
        lowered = model.lower_local_matmul(64, 256)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule")
        # must be the text format the rust parser accepts, not a proto dump
        assert "ENTRY" in text
        assert "f32[64,256]" in text

    def test_rank1_hlo_mentions_shapes(self):
        lowered = model.lower_rank1_update(128, 512)
        text = aot.to_hlo_text(lowered)
        assert "f32[128,512]" in text

    def test_tuple_return_convention(self):
        # the rust side unwraps with to_tuple1: root must be a tuple
        lowered = model.lower_local_matmul(64, 256)
        text = aot.to_hlo_text(lowered)
        root_lines = [l for l in text.splitlines() if "ROOT" in l]
        assert any("tuple" in l for l in root_lines), root_lines


class TestBuildAll(object):
    def test_build_all_writes_manifest(self, tmp_path):
        out = str(tmp_path / "artifacts")
        lines = aot.build_all(out)
        n_expected = (
            len(model.MATMUL_BUCKETS)
            + len(model.UPDATE_BUCKETS)
            + len(model.BLOCK_UPDATE_BUCKETS)
        )
        assert len(lines) == n_expected
        manifest = os.path.join(out, "manifest.txt")
        assert os.path.exists(manifest)
        with open(manifest) as f:
            rows = [l.split() for l in f.read().strip().splitlines()]
        assert len(rows) == n_expected
        for row in rows:
            # name kind dims... file
            assert row[1] in ("matmul1d", "rank1", "block2d")
            assert row[-1].endswith(".hlo.txt")
            assert os.path.exists(os.path.join(out, row[-1]))

    def test_artifact_numerics_via_jax_roundtrip(self, tmp_path):
        # execute the lowered computation (pre-AOT) and compare to numpy —
        # the rust integration test repeats this through PJRT
        rng = np.random.default_rng(5)
        a = rng.standard_normal((64, 256)).astype(np.float32)
        b = rng.standard_normal((256, 256)).astype(np.float32)
        got = model.local_matmul(jnp.asarray(a), jnp.asarray(b))
        np.testing.assert_allclose(np.asarray(got), a @ b, rtol=1e-4, atol=1e-3)
