"""L2 JAX compute graph: the local computational kernels of the paper's
applications, built on the L1 Pallas kernels.

The 1D matmul application's *local compute* on a worker owning an
``nb``-row slice is ``C_b[nb, n] = A_b[nb, n] @ B[n, n]`` — n repetitions
of the paper's rank-1 update fused into one blocked matmul. The 2D app's
local compute per pivot step is the ``block_update``. Both are jitted jax
functions calling the Pallas kernels, so the AOT lowering captures the
kernel inside the same HLO module the rust runtime executes.
"""

import jax
import jax.numpy as jnp

from .kernels import block_update, matmul_kernel, rank1_update


def local_matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """The 1D worker's local compute: C_b = A_b @ B (Pallas-tiled)."""
    return matmul_kernel(a, b)


def panel_update(c: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """One step of the paper's 1D kernel: C_b += A_b[:, k:k+1] · B[k:k+1, :]."""
    return rank1_update(c, a, b)


def pivot_update(c: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """The 2D worker's pivot update: C_b += A_b · B_b (block panel)."""
    return block_update(c, a, b)


def pad_to(x: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    """Zero-pad a 2D array up to (rows, cols) — the runtime's bucket fit."""
    r, c = x.shape
    assert rows >= r and cols >= c, f"cannot pad {x.shape} down to ({rows},{cols})"
    return jnp.pad(x, ((0, rows - r), (0, cols - c)))


# --- AOT bucket family -----------------------------------------------------
#
# XLA executables have static shapes; the rust runtime rounds a worker's
# slice up to the nearest bucket and rescales measured time by the
# true/bucket unit ratio (runtime/artifact.rs). Buckets are multiples of
# the kernel block edge so the Pallas grid always divides evenly.

#: (nb, n) shapes for the 1D local matmul: C[nb, n] = A[nb, n] @ B[n, n].
MATMUL_BUCKETS: list[tuple[int, int]] = [
    (64, 256),
    (128, 256),
    (256, 256),
    (64, 512),
    (128, 512),
    (256, 512),
    (512, 512),
]

#: (nb, n) shapes for the rank-1 update benchmark kernel.
UPDATE_BUCKETS: list[tuple[int, int]] = [
    (64, 512),
    (128, 512),
    (256, 512),
    (512, 512),
]

#: (mb, nb, t) shapes for the 2D pivot update.
BLOCK_UPDATE_BUCKETS: list[tuple[int, int, int]] = [
    (128, 128, 64),
    (256, 256, 64),
]


def lower_local_matmul(nb: int, n: int):
    """Lower the 1D local matmul at bucket (nb, n) to a jax Lowered."""
    sa = jax.ShapeDtypeStruct((nb, n), jnp.float32)
    sb = jax.ShapeDtypeStruct((n, n), jnp.float32)
    return jax.jit(lambda a, b: (local_matmul(a, b),)).lower(sa, sb)


def lower_rank1_update(nb: int, n: int):
    """Lower the rank-1 update at bucket (nb, n)."""
    sc = jax.ShapeDtypeStruct((nb, n), jnp.float32)
    sa = jax.ShapeDtypeStruct((nb, 1), jnp.float32)
    sb = jax.ShapeDtypeStruct((1, n), jnp.float32)
    return jax.jit(lambda c, a, b: (panel_update(c, a, b),)).lower(sc, sa, sb)


def lower_block_update(mb: int, nb: int, t: int):
    """Lower the 2D pivot update at bucket (mb, nb, t)."""
    sc = jax.ShapeDtypeStruct((mb, nb), jnp.float32)
    sa = jax.ShapeDtypeStruct((mb, t), jnp.float32)
    sb = jax.ShapeDtypeStruct((t, nb), jnp.float32)
    return jax.jit(lambda c, a, b: (pivot_update(c, a, b),)).lower(sc, sa, sb)
