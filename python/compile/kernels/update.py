"""L1 Pallas kernel: the paper's core computational kernels as updates.

Two variants:

- :func:`rank1_update` — the 1D app's kernel (paper Fig 4b): one step of
  the outer-product update ``C[nb, n] += A[nb, 1] · B[1, n]``. This is the
  unit DFPA benchmarks: executing ``nb·n`` computation units.
- :func:`block_update` — the 2D app's kernel (paper Fig 7b):
  ``C[mb, nb] += A[mb, t] · B[t, nb]`` where the matrix elements are b×b
  blocks flattened into the ``t`` contraction dim.

Both tile over the output with VMEM-sized blocks; the rank-1 contraction
has no k loop so each grid step is a single fused multiply-add over its
tile — bandwidth-bound on any hardware, which is precisely why the paper's
speed functions are memory-regime-shaped.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import MXU_TILE


def _rank1_kernel(c_ref, a_ref, b_ref, o_ref):
    o_ref[...] = c_ref[...] + (
        a_ref[...] * b_ref[...]
    ).astype(c_ref.dtype)


@jax.jit
def rank1_update(c: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """C[nb, n] += A[nb, 1] · B[1, n] (broadcast outer product).

    Tiles the output into (b_rows × b_cols) VMEM blocks; A broadcasts along
    columns, B along rows.
    """
    nb, n = c.shape
    assert a.shape == (nb, 1), f"A shape {a.shape} != ({nb}, 1)"
    assert b.shape == (1, n), f"B shape {b.shape} != (1, {n})"
    br, bc = min(nb, MXU_TILE), min(n, MXU_TILE)
    assert nb % br == 0 and n % bc == 0, (
        f"shape ({nb},{n}) not divisible by blocks ({br},{bc})"
    )
    grid = (nb // br, n // bc)
    return pl.pallas_call(
        _rank1_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((br, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bc), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nb, n), c.dtype),
        interpret=True,
    )(c, a, b)


def _block_update_kernel(c_ref, a_ref, b_ref, o_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _seed():
        o_ref[...] = c_ref[...]

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@jax.jit
def block_update(c: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """C[mb, nb] += A[mb, t] · B[t, nb] — the 2D app's pivot update."""
    mb, nb = c.shape
    mb2, t = a.shape
    t2, nb2 = b.shape
    assert mb == mb2 and nb == nb2 and t == t2, (
        f"shape mismatch: C{c.shape} A{a.shape} B{b.shape}"
    )
    bm, bn, bk = min(mb, MXU_TILE), min(nb, MXU_TILE), min(t, MXU_TILE)
    assert mb % bm == 0 and nb % bn == 0 and t % bk == 0
    n_k = t // bk
    grid = (mb // bm, nb // bn, n_k)
    return pl.pallas_call(
        functools.partial(_block_update_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mb, nb), c.dtype),
        interpret=True,
    )(c, a, b)
