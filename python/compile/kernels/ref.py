"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
must match its oracle to float tolerance under pytest/hypothesis sweeps.
"""

import jax.numpy as jnp


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """C = A @ B with f32 accumulation (the MXU contract)."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def rank1_update_ref(c: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """The paper's 1D core kernel: C[nb, n] += A[nb, 1] · B[1, n].

    One step of the outer-product matrix update (Fig 4b).
    """
    return c + (a @ b).astype(c.dtype)


def block_update_ref(c: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """The 2D app's core kernel: C[mb, nb] += A[mb, t] · B[t, nb] (Fig 7b)."""
    return c + jnp.matmul(a, b, preferred_element_type=jnp.float32).astype(c.dtype)
