"""L1 Pallas kernels and their pure-jnp oracles."""

from . import matmul, ref, update
from .matmul import matmul as matmul_kernel
from .update import block_update, rank1_update

__all__ = ["matmul", "ref", "update", "matmul_kernel", "rank1_update", "block_update"]
