"""L1 Pallas kernel: VMEM-tiled blocked matmul for the MXU.

Hardware adaptation (DESIGN.md §4): the paper's local compute was
GotoBLAS2's L2-blocked dgemm on 2005-era CPUs. The TPU-shaped equivalent
tiles for VMEM with ``BlockSpec`` and feeds the 128×128 MXU systolic array:
the grid walks (i, j) output tiles with an inner k loop accumulating in a
VMEM scratch block, which is exactly the HBM↔VMEM schedule GotoBLAS
expressed with cache blocking.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers to plain HLO, which both the python
tests and the rust runtime execute. Real-TPU block-shape choices are
justified by the VMEM/MXU estimates in EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# MXU-native tile edge. Block shapes are min(dim, 128) so small problems
# stay single-block while large ones tile the systolic array exactly.
MXU_TILE = 128


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    """One (i, j, k) grid step: acc += A[i,k] @ B[k,j]; flush on last k."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def block_shape(m: int, k: int, n: int) -> tuple[int, int, int]:
    """Choose (bm, bk, bn) tiles: MXU-sized, never exceeding the problem."""
    return min(m, MXU_TILE), min(k, MXU_TILE), min(n, MXU_TILE)


def vmem_bytes(m: int, k: int, n: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM footprint of one grid step: A, B, O tiles + f32 acc.

    Used by the perf notes: must stay well under ~16 MiB/core VMEM; the
    default 128³ f32 tiling needs 4·128·128·(3+1) = 256 KiB — room for
    double-buffering by the pipeline emitter.
    """
    bm, bk, bn = block_shape(m, k, n)
    return dtype_bytes * (bm * bk + bk * bn + bm * bn) + 4 * bm * bn


@functools.partial(jax.jit, static_argnames=("debug",))
def matmul(a: jnp.ndarray, b: jnp.ndarray, debug: bool = False) -> jnp.ndarray:
    """C[m, n] = A[m, k] @ B[k, n] via the Pallas kernel.

    Requires every dimension to be divisible by its block edge (the AOT
    bucket shapes are all multiples of 64/128; the runtime pads to the
    bucket). f32 accumulation regardless of input dtype.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {a.shape} @ {b.shape}"
    bm, bk, bn = block_shape(m, k, n)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (
        f"shape ({m},{k},{n}) not divisible by blocks ({bm},{bk},{bn}); "
        "pad to the AOT bucket first"
    )
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
        debug=debug,
    )(a, b)
