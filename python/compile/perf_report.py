"""L1/L2 performance report: VMEM footprint and MXU-utilization estimates
for the Pallas kernel block shapes, plus an HLO structure check on the
lowered modules.

Pallas runs under interpret=True on this CPU-only plugin, so wallclock is
CPU-numpy time — NOT a TPU proxy. Real-TPU performance is estimated
structurally (see DESIGN.md §4 and EXPERIMENTS.md §Perf):

- VMEM: the three input/output tiles plus the f32 accumulator must fit in
  ~16 MiB/core with room for the pipeline emitter to double-buffer;
- MXU: a (bm, bk)·(bk, bn) tile update keeps the 128×128 systolic array
  fully occupied iff every edge is ≥128; utilization estimate is
  (bm·bk·bn)/(128³·ceil(bm/128)·ceil(bk/128)·ceil(bn/128)).

Usage: cd python && python -m compile.perf_report
"""

import math

from . import model
from .kernels.matmul import block_shape, vmem_bytes


def mxu_utilization(bm: int, bk: int, bn: int) -> float:
    tiles = (
        math.ceil(bm / 128) * math.ceil(bk / 128) * math.ceil(bn / 128)
    )
    return (bm * bk * bn) / (128**3 * tiles)


def hlo_stats(lowered) -> dict:
    text = str(lowered.compiler_ir("stablehlo"))
    return {
        "lines": len(text.splitlines()),
        "dots": text.count("stablehlo.dot"),
        "loops": text.count("stablehlo.while"),
        "transposes": text.count("stablehlo.transpose"),
    }


def main() -> None:
    print("=== L1: block-shape sweep (VMEM + MXU estimates) ===")
    print(f"{'shape':>20} {'blocks':>15} {'VMEM/step':>12} {'MXU util':>9}")
    for m, k, n in [
        (64, 256, 256),
        (128, 512, 512),
        (256, 512, 512),
        (512, 512, 512),
        (1024, 1024, 1024),
    ]:
        bm, bk, bn = block_shape(m, k, n)
        vb = vmem_bytes(m, k, n)
        util = mxu_utilization(bm, bk, bn)
        print(
            f"{f'{m}x{k}x{n}':>20} {f'({bm},{bk},{bn})':>15} "
            f"{vb / 1024:>10.0f}KB {util:>8.1%}"
        )
    print(
        "\n128³ tiles: 256 KiB VMEM/step → 64 steps double-buffer in 16 MiB;"
        "\nMXU fully occupied (1.00) whenever every dim ≥ 128."
    )

    print("\n=== L2: lowered-HLO structure (no redundant recomputation) ===")
    for name, lowered in [
        ("local_matmul 512x512", model.lower_local_matmul(512, 512)),
        ("rank1_update 512x512", model.lower_rank1_update(512, 512)),
        ("block_update 256x256x64", model.lower_block_update(256, 256, 64)),
    ]:
        s = hlo_stats(lowered)
        print(
            f"  {name:<26}  {s['lines']:>5} lines, {s['dots']} dot ops, "
            f"{s['loops']} loops, {s['transposes']} transposes"
        )
    print("\n(one grid loop per kernel, no transposes → XLA fuses the "
          "interpret-mode body; nothing is recomputed across k steps)")


if __name__ == "__main__":
    main()
