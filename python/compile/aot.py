"""AOT bridge: lower the L2 model functions to HLO **text** artifacts.

HLO text — NOT ``lowered.compile()`` or proto ``.serialize()`` — is the
interchange format: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction
ids which the runtime's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser on the rust side reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Usage::

    python -m compile.aot --out ../artifacts

Writes one ``<name>.hlo.txt`` per bucket plus ``manifest.txt`` with lines

    <name> <kind> <shape...> <file>

that ``rust/src/runtime/artifact.rs`` parses.
"""

import argparse
import os

from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_all(out_dir: str) -> list[str]:
    os.makedirs(out_dir, exist_ok=True)
    manifest_lines: list[str] = []

    def emit(name: str, kind: str, dims: tuple[int, ...], lowered) -> None:
        fname = f"{name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        dim_str = " ".join(str(d) for d in dims)
        manifest_lines.append(f"{name} {kind} {dim_str} {fname}")
        print(f"  wrote {fname} ({len(text)} chars)")

    for nb, n in model.MATMUL_BUCKETS:
        emit(
            f"matmul_nb{nb}_n{n}",
            "matmul1d",
            (nb, n),
            model.lower_local_matmul(nb, n),
        )
    for nb, n in model.UPDATE_BUCKETS:
        emit(
            f"update_nb{nb}_n{n}",
            "rank1",
            (nb, n),
            model.lower_rank1_update(nb, n),
        )
    for mb, nb, t in model.BLOCK_UPDATE_BUCKETS:
        emit(
            f"blockupd_mb{mb}_nb{nb}_t{t}",
            "block2d",
            (mb, nb, t),
            model.lower_block_update(mb, nb, t),
        )

    manifest = os.path.join(out_dir, "manifest.txt")
    with open(manifest, "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"  wrote manifest.txt ({len(manifest_lines)} artifacts)")
    return manifest_lines


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    args = ap.parse_args()
    print(f"AOT-lowering kernels to {args.out}")
    build_all(args.out)


if __name__ == "__main__":
    main()
